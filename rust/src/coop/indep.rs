//! Independent Minibatching — the baseline (paper §2.3).
//!
//! Each PE draws its own `b`-sized seed batch and samples a full MFG with
//! **no communication**. The price is duplicate work: the same vertex can
//! appear in several PEs' L-hop neighborhoods and is then fetched and
//! processed once *per PE*. [`IndepSample::duplication`] measures exactly
//! that overlap — the quantity cooperative minibatching eliminates.

use crate::graph::VertexId;
use crate::sampling::{Mfg, Sampler};

/// Per-PE MFGs for one independent global step.
#[derive(Clone, Debug)]
pub struct IndepSample {
    pub per_pe: Vec<Mfg>,
}

impl IndepSample {
    pub fn num_pes(&self) -> usize {
        self.per_pe.len()
    }

    /// max over PEs of |S^l| (Table 7's reduction).
    pub fn max_vertices(&self, l: usize) -> usize {
        self.per_pe.iter().map(|m| m.layer_vertices[l].len()).max().unwrap_or(0)
    }

    pub fn max_edges(&self, l: usize) -> usize {
        self.per_pe.iter().map(|m| m.layer_edges[l].num_edges()).max().unwrap_or(0)
    }

    /// Σ over PEs of |S^l| — the actual work performed.
    pub fn sum_vertices(&self, l: usize) -> usize {
        self.per_pe.iter().map(|m| m.layer_vertices[l].len()).sum()
    }

    /// |∪_p S_p^l| — the work that *would* suffice without duplication.
    pub fn union_vertices(&self, l: usize) -> usize {
        let mut v: Vec<VertexId> = self
            .per_pe
            .iter()
            .flat_map(|m| m.layer_vertices[l].iter().copied())
            .collect();
        v.sort_unstable();
        v.dedup();
        v.len()
    }

    /// Duplication factor at layer `l`: performed / necessary (≥ 1).
    pub fn duplication(&self, l: usize) -> f64 {
        let union = self.union_vertices(l);
        if union == 0 {
            1.0
        } else {
            self.sum_vertices(l) as f64 / union as f64
        }
    }
}

/// Sample one independent global step: PE `p` gets `per_pe_seeds[p]` and
/// samples alone. Samplers may share a batch seed (harmless — there is no
/// cross-PE interaction to exploit it).
pub fn sample_independent(
    per_pe_samplers: &mut [Sampler<'_>],
    per_pe_seeds: &[Vec<VertexId>],
) -> IndepSample {
    assert_eq!(per_pe_samplers.len(), per_pe_seeds.len());
    let per_pe = per_pe_samplers
        .iter_mut()
        .zip(per_pe_seeds.iter())
        .map(|(s, seeds)| s.sample_mfg(seeds))
        .collect();
    IndepSample { per_pe }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::{SamplerConfig, SamplerKind};

    #[test]
    fn duplication_exceeds_one_with_overlapping_batches() {
        let g = generate::chung_lu(2000, 20.0, 2.3, 40);
        let cfg = SamplerConfig::default();
        let mut samplers: Vec<_> =
            (0..4).map(|p| cfg.build(SamplerKind::Labor0, &g, 100 + p)).collect();
        let seeds: Vec<Vec<u32>> = (0..4).map(|p| (p * 64..(p + 1) * 64).collect()).collect();
        let s = sample_independent(&mut samplers, &seeds);
        assert_eq!(s.num_pes(), 4);
        // deep layers overlap heavily on a power-law graph
        let dup3 = s.duplication(3);
        assert!(dup3 > 1.2, "expected duplicated work at layer 3, got {dup3}");
        // seeds are disjoint, so layer 0 has no duplication
        assert!((s.duplication(0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn duplication_grows_with_pe_count() {
        // More PEs at fixed global batch ⇒ more duplicate work (paper §3).
        let g = generate::chung_lu(2000, 20.0, 2.3, 41);
        let cfg = SamplerConfig::default();
        let global: Vec<u32> = (0..512).collect();
        let dup_at = |p_count: usize| -> f64 {
            let b = global.len() / p_count;
            let mut samplers: Vec<_> =
                (0..p_count).map(|p| cfg.build(SamplerKind::Labor0, &g, 7 + p as u64)).collect();
            let seeds: Vec<Vec<u32>> =
                (0..p_count).map(|p| global[p * b..(p + 1) * b].to_vec()).collect();
            sample_independent(&mut samplers, &seeds).duplication(3)
        };
        let d2 = dup_at(2);
        let d8 = dup_at(8);
        assert!(d8 > d2, "duplication must grow with P: P=2 {d2} vs P=8 {d8}");
    }
}
