//! Multi-batch measurement engine with a real thread-per-PE runtime.
//!
//! Drives `warmup + measure` minibatches of either mode over a dataset
//! and aggregates the per-stage counts the paper's complexity model
//! (Table 1) consumes: per-layer vertex/edge/communication counts
//! (max-over-PE, averaged over batches), feature-cache traffic, and real
//! CPU wall-clock per stage. The repro harnesses for Tables 4–7 and
//! Figure 5 are thin wrappers around [`run`].
//!
//! ## Execution modes
//!
//! * [`ExecMode::Threaded`] (default) — **one OS thread per PE** (scoped
//!   threads). Each PE owns its sampler, its seed RNG stream, and its LRU
//!   cache behind the thread boundary; cooperative sampling exchanges ids
//!   over the live channel fabric ([`super::all_to_all::Fabric`]) with a
//!   barrier per all-to-all round. Sampling and feature loading of
//!   different PEs genuinely overlap: [`EngineReport::wall_batch_ms`]
//!   (batch wall-clock) drops below the *serial* mode's batch wall-clock
//!   for the identical workload — the concurrency the paper's
//!   max-over-PE cost model assumes (`benches/bench_coop.rs` prints the
//!   comparison).
//! * [`ExecMode::Serial`] — the single-threaded reference (debugging
//!   fallback; CLI `--exec serial`).
//!
//! Both modes are **bit-identical**: per-PE RNG streams are split from
//! the engine seed the same way, samplers share counter-based coins, and
//! per-batch statistics are reduced through one code path
//! ([`reduce`]/[`finalize`]), so every count field of the report matches
//! exactly (tested below and in `tests/integration_coop.rs`). Only the
//! wall-clock fields differ.

use super::all_to_all::Fabric;
use super::cache::LruCache;
use super::coop_sampler::{sample_cooperative, sample_cooperative_pe, PeLayer};
use super::feature_loader::load_pe;
use super::indep::sample_independent;
use crate::graph::{Dataset, Partition, VertexId};
use crate::sampling::{Mfg, SamplerConfig, SamplerKind};
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;
use std::sync::Mutex;

/// Minibatching mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Independent,
    Cooperative,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Independent => "Indep",
            Mode::Cooperative => "Coop",
        }
    }
}

/// How the engine schedules PE work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded reference loop (debugging fallback).
    Serial,
    /// One OS thread per PE with a live channel fabric (default).
    Threaded,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Some(ExecMode::Serial),
            "threaded" | "parallel" => Some(ExecMode::Threaded),
            _ => None,
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: Mode,
    /// thread-per-PE or the serial reference loop.
    pub exec: ExecMode,
    pub num_pes: usize,
    /// per-PE batch size b (global batch = b · P).
    pub batch_per_pe: usize,
    pub kind: SamplerKind,
    pub sampler: SamplerConfig,
    /// LRU capacity per PE (vertex rows).
    pub cache_per_pe: usize,
    pub warmup_batches: usize,
    pub measure_batches: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: Mode::Independent,
            exec: ExecMode::Threaded,
            num_pes: 4,
            batch_per_pe: 1024,
            kind: SamplerKind::Labor0,
            sampler: SamplerConfig::default(),
            cache_per_pe: 100_000,
            warmup_batches: 4,
            measure_batches: 16,
            seed: 0xC001,
        }
    }
}

/// Aggregated per-stage counts (averages of per-batch max-over-PE).
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub mode: String,
    pub num_pes: usize,
    /// |S^l| per layer (len L+1; l=0 is the seed count).
    pub s: Vec<f64>,
    /// |E^l| per layer (len L).
    pub e: Vec<f64>,
    /// |S̃^{l+1}| per layer (coop; len L; 0 for indep).
    pub tilde: Vec<f64>,
    /// cross-PE portion c·|S̃^{l+1}| (coop; len L).
    pub cross: Vec<f64>,
    /// feature stage (per batch averages).
    pub feat_requested: f64,
    pub feat_misses: f64,
    pub feat_fabric_rows: f64,
    pub cache_miss_rate: f64,
    /// duplication factor at the deepest layer (indep only; 1.0 for coop).
    pub dup_factor: f64,
    /// measured CPU stage time (ms per batch, **summed across PEs** —
    /// each PE's own elapsed sampling / feature-loading time; in
    /// threaded mode this includes time blocked in the exchange, so the
    /// sum over PEs is an upper bound on useful work).
    pub wall_sampling_ms: f64,
    pub wall_feature_ms: f64,
    /// wall-clock per batch (ms). Threaded mode: elapsed between the
    /// batch-start and batch-end barriers, i.e. the real concurrent
    /// latency; compare against a `Serial` run of the same config for
    /// the concurrency speedup. Serial mode: ≈ the stage sum by
    /// construction.
    pub wall_batch_ms: f64,
}

/// One PE's raw counts for one batch (deposited by the PE thread, or
/// synthesized by the serial loop — both feed [`reduce`]).
struct PeBatch {
    /// |S_p^l| for l in 0..=L (final entry = owned input vertices).
    counts_s: Vec<u64>,
    counts_e: Vec<u64>,
    counts_tilde: Vec<u64>,
    counts_cross: Vec<u64>,
    requested: u64,
    misses: u64,
    fabric: u64,
    /// S_p^L vertex list (indep measuring only; feeds the duplication
    /// factor union).
    input_vertices: Option<Vec<VertexId>>,
    samp_ms: f64,
    feat_ms: f64,
}

/// Cross-PE reduction of one batch (max-over-PE counts, totals, dup).
struct BatchStats {
    s: Vec<u64>,
    e: Vec<u64>,
    tilde: Vec<u64>,
    cross: Vec<u64>,
    feat_requested: u64,
    feat_misses: u64,
    feat_fabric_rows: u64,
    total_requested: u64,
    total_misses: u64,
    dup: f64,
    samp_ms: f64,
    feat_ms: f64,
    wall_ms: f64,
}

/// Per-PE seed RNG stream, split deterministically from the engine seed
/// (identical in serial and threaded modes).
fn pe_seed(seed: u64, pe: usize) -> u64 {
    seed ^ ((pe as u64 + 1) * 0x9E37)
}

/// Assemble one PE's cooperative-mode batch record: pull the owned input
/// rows through this PE's cache and collect per-layer counts. Shared by
/// both exec modes so the construction can never drift between them
/// (stage times are assigned by the caller).
fn coop_pe_batch(
    layers: usize,
    pe_layers: &[&PeLayer],
    final_owned: &[VertexId],
    cache: &mut LruCache,
) -> PeBatch {
    let (requested, misses) = load_pe(final_owned, cache);
    let mut counts_s: Vec<u64> = pe_layers.iter().map(|pl| pl.owned.len() as u64).collect();
    counts_s.push(final_owned.len() as u64);
    PeBatch {
        counts_s,
        counts_e: pe_layers.iter().map(|pl| pl.edges as u64).collect(),
        counts_tilde: pe_layers.iter().map(|pl| pl.tilde.len() as u64).collect(),
        counts_cross: pe_layers.iter().map(|pl| pl.cross as u64).collect(),
        requested,
        misses,
        fabric: pe_layers[layers - 1].cross as u64,
        input_vertices: None,
        samp_ms: 0.0,
        feat_ms: 0.0,
    }
}

/// Assemble one PE's independent-mode batch record from its private MFG
/// (shared by both exec modes; `keep_inputs` retains the S^L vertex list
/// for the duplication-factor union on measured batches).
fn indep_pe_batch(mfg: &Mfg, layers: usize, keep_inputs: bool, cache: &mut LruCache) -> PeBatch {
    let (requested, misses) = load_pe(mfg.input_vertices(), cache);
    PeBatch {
        counts_s: mfg.vertex_counts().iter().map(|&c| c as u64).collect(),
        counts_e: mfg.edge_counts().iter().map(|&c| c as u64).collect(),
        counts_tilde: vec![0; layers],
        counts_cross: vec![0; layers],
        requested,
        misses,
        fabric: 0,
        input_vertices: if keep_inputs { Some(mfg.input_vertices().to_vec()) } else { None },
        samp_ms: 0.0,
        feat_ms: 0.0,
    }
}

/// Per-PE training shards. Coop: PE p draws seeds from train ∩ V_p
/// (Algorithm 1). Indep: the training set is sharded round-robin
/// (classic data parallelism).
fn make_shards(dataset: &Dataset, part: &Partition, cfg: &EngineConfig) -> Vec<Vec<VertexId>> {
    match cfg.mode {
        Mode::Cooperative => {
            let mut by_owner: Vec<Vec<VertexId>> = vec![Vec::new(); cfg.num_pes];
            for &v in &dataset.train {
                by_owner[part.part_of(v)].push(v);
            }
            by_owner
        }
        Mode::Independent => {
            let mut shards: Vec<Vec<VertexId>> = vec![Vec::new(); cfg.num_pes];
            for (i, &v) in dataset.train.iter().enumerate() {
                shards[i % cfg.num_pes].push(v);
            }
            shards
        }
    }
}

/// Run the engine over `dataset` with partition `part` (required for
/// cooperative mode; independent mode uses it only to shard the training
/// set).
pub fn run(dataset: &Dataset, part: &Partition, cfg: &EngineConfig) -> EngineReport {
    assert_eq!(part.num_parts, cfg.num_pes, "partition/PE mismatch");
    assert!(cfg.sampler.layers >= 1, "engine needs at least one GNN layer");
    let shards = make_shards(dataset, part, cfg);
    let stats = match cfg.exec {
        ExecMode::Serial => run_serial(dataset, part, cfg, &shards),
        ExecMode::Threaded => run_threaded(dataset, part, cfg, &shards),
    };
    finalize(cfg, &stats)
}

/// Single-threaded reference loop.
fn run_serial(
    dataset: &Dataset,
    part: &Partition,
    cfg: &EngineConfig,
    shards: &[Vec<VertexId>],
) -> Vec<BatchStats> {
    let g = &dataset.graph;
    let layers = cfg.sampler.layers;
    let p_count = cfg.num_pes;
    let mut samplers: Vec<_> =
        (0..p_count).map(|_| cfg.sampler.build(cfg.kind, g, cfg.seed)).collect();
    let mut caches: Vec<LruCache> =
        (0..p_count).map(|_| LruCache::new(cfg.cache_per_pe)).collect();
    let mut seed_rngs: Vec<Pcg64> =
        (0..p_count).map(|p| Pcg64::new(pe_seed(cfg.seed, p))).collect();
    let mut out: Vec<BatchStats> = Vec::with_capacity(cfg.measure_batches);

    for batch in 0..(cfg.warmup_batches + cfg.measure_batches) {
        let measuring = batch >= cfg.warmup_batches;
        let wall = Timer::start();
        let per_pe_seeds: Vec<Vec<VertexId>> = shards
            .iter()
            .zip(seed_rngs.iter_mut())
            .map(|(shard, rng)| {
                let b = cfg.batch_per_pe.min(shard.len());
                rng.sample_distinct(shard.len(), b)
                    .into_iter()
                    .map(|i| shard[i as usize])
                    .collect()
            })
            .collect();

        let (mut per_pe, samp_ms, feat_ms): (Vec<PeBatch>, f64, f64) = match cfg.mode {
            Mode::Cooperative => {
                let t = Timer::start();
                let coop = sample_cooperative(g, part, &mut samplers, &per_pe_seeds, layers);
                let samp_ms = t.elapsed_ms();
                let t = Timer::start();
                let per_pe = (0..p_count)
                    .map(|p| {
                        let pe_layers: Vec<&PeLayer> =
                            (0..layers).map(|l| &coop.layers[l][p]).collect();
                        coop_pe_batch(layers, &pe_layers, &coop.final_owned[p], &mut caches[p])
                    })
                    .collect();
                (per_pe, samp_ms, t.elapsed_ms())
            }
            Mode::Independent => {
                let t = Timer::start();
                let s = sample_independent(&mut samplers, &per_pe_seeds);
                let samp_ms = t.elapsed_ms();
                let t = Timer::start();
                let per_pe = s
                    .per_pe
                    .iter()
                    .enumerate()
                    .map(|(p, mfg)| indep_pe_batch(mfg, layers, measuring, &mut caches[p]))
                    .collect();
                (per_pe, samp_ms, t.elapsed_ms())
            }
        };
        for s in samplers.iter_mut() {
            s.advance_batch();
        }
        // capture the batch latency before the cross-PE reduction so the
        // reported wall clock covers exactly the batch's work
        let wall_ms = wall.elapsed_ms();
        if measuring {
            // serial does all PEs' work inline: assign the batch stage
            // times to one entry so the cross-PE sum matches semantics
            per_pe[0].samp_ms = samp_ms;
            per_pe[0].feat_ms = feat_ms;
            let mut bs = reduce(cfg.mode, layers, &per_pe);
            bs.wall_ms = wall_ms;
            out.push(bs);
        }
    }
    out
}

/// Converts a PE-thread panic into a fast process abort. `std::sync::
/// Barrier` has no poisoning and every surviving endpoint keeps live
/// `Sender` clones for all peers, so a single panicking PE would
/// otherwise leave the remaining threads blocked forever in `wait()` /
/// `recv()` — a silent CI hang instead of a failure. A panic inside a PE
/// thread is always a bug; after the default hook prints it, failing the
/// whole process immediately is strictly better than deadlock.
struct AbortOnPeerPanic;

impl Drop for AbortOnPeerPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!("engine: PE thread panicked; aborting to avoid deadlocking peer PEs");
            std::process::abort();
        }
    }
}

/// Thread-per-PE runtime: spawn one scoped OS thread per PE; each owns
/// its sampler, seed-RNG stream, and LRU cache, and exchanges ids over
/// the live channel fabric. PE 0 reduces the per-batch deposits between
/// barriers.
fn run_threaded(
    dataset: &Dataset,
    part: &Partition,
    cfg: &EngineConfig,
    shards: &[Vec<VertexId>],
) -> Vec<BatchStats> {
    let g = &dataset.graph;
    let layers = cfg.sampler.layers;
    let p_count = cfg.num_pes;
    let total = cfg.warmup_batches + cfg.measure_batches;
    let barrier = std::sync::Barrier::new(p_count);
    let endpoints = Fabric::endpoints(p_count);
    let deposits: Vec<Mutex<Option<PeBatch>>> = (0..p_count).map(|_| Mutex::new(None)).collect();
    let collected: Mutex<Vec<BatchStats>> = Mutex::new(Vec::with_capacity(cfg.measure_batches));

    std::thread::scope(|scope| {
        let barrier = &barrier;
        let deposits = &deposits;
        let collected = &collected;
        for (pe, mut ep) in endpoints.into_iter().enumerate() {
            let shard = &shards[pe];
            scope.spawn(move || {
                let _abort_guard = AbortOnPeerPanic;
                let mut sampler = cfg.sampler.build(cfg.kind, g, cfg.seed);
                let mut cache = LruCache::new(cfg.cache_per_pe);
                let mut seed_rng = Pcg64::new(pe_seed(cfg.seed, pe));
                for batch in 0..total {
                    let measuring = batch >= cfg.warmup_batches;
                    // align all PEs so the wall timer sees the true
                    // concurrent latency of this batch
                    barrier.wait();
                    let wall = Timer::start();
                    let b = cfg.batch_per_pe.min(shard.len());
                    let seeds: Vec<VertexId> = seed_rng
                        .sample_distinct(shard.len(), b)
                        .into_iter()
                        .map(|i| shard[i as usize])
                        .collect();
                    let pb = match cfg.mode {
                        Mode::Cooperative => {
                            let t = Timer::start();
                            let ps = sample_cooperative_pe(
                                g,
                                part,
                                &mut sampler,
                                &mut ep,
                                seeds,
                                layers,
                            );
                            let samp_ms = t.elapsed_ms();
                            let t = Timer::start();
                            let pe_layers: Vec<&PeLayer> = ps.layers.iter().collect();
                            let mut pb =
                                coop_pe_batch(layers, &pe_layers, &ps.final_owned, &mut cache);
                            pb.samp_ms = samp_ms;
                            pb.feat_ms = t.elapsed_ms();
                            pb
                        }
                        Mode::Independent => {
                            let t = Timer::start();
                            let mfg = sampler.sample_mfg(&seeds);
                            let samp_ms = t.elapsed_ms();
                            let t = Timer::start();
                            let mut pb = indep_pe_batch(&mfg, layers, measuring, &mut cache);
                            pb.samp_ms = samp_ms;
                            pb.feat_ms = t.elapsed_ms();
                            pb
                        }
                    };
                    sampler.advance_batch();
                    if measuring {
                        *deposits[pe].lock().unwrap() = Some(pb);
                    }
                    // every PE finished this batch's work
                    barrier.wait();
                    // batch latency ends at the batch-end barrier — the
                    // cross-PE reduction below is bookkeeping, not batch
                    // work, and must not inflate the reported wall clock
                    let wall_ms = wall.elapsed_ms();
                    if pe == 0 && measuring {
                        let per_pe: Vec<PeBatch> = deposits
                            .iter()
                            .map(|d| d.lock().unwrap().take().expect("missing PE deposit"))
                            .collect();
                        let mut bs = reduce(cfg.mode, layers, &per_pe);
                        bs.wall_ms = wall_ms;
                        collected.lock().unwrap().push(bs);
                    }
                    // other PEs wait at the next batch's start barrier
                    // until PE 0 finished reducing, so deposits are never
                    // overwritten mid-reduce
                }
            });
        }
    });
    collected.into_inner().unwrap()
}

/// Max/total reduction of one batch across PEs — shared by both exec
/// modes so the aggregated numbers are bit-identical.
fn reduce(mode: Mode, layers: usize, per_pe: &[PeBatch]) -> BatchStats {
    let mut bs = BatchStats {
        s: vec![0; layers + 1],
        e: vec![0; layers],
        tilde: vec![0; layers],
        cross: vec![0; layers],
        feat_requested: 0,
        feat_misses: 0,
        feat_fabric_rows: 0,
        total_requested: 0,
        total_misses: 0,
        dup: 1.0,
        samp_ms: 0.0,
        feat_ms: 0.0,
        wall_ms: 0.0,
    };
    for pb in per_pe {
        for l in 0..=layers {
            bs.s[l] = bs.s[l].max(pb.counts_s[l]);
        }
        for l in 0..layers {
            bs.e[l] = bs.e[l].max(pb.counts_e[l]);
            bs.tilde[l] = bs.tilde[l].max(pb.counts_tilde[l]);
            bs.cross[l] = bs.cross[l].max(pb.counts_cross[l]);
        }
        bs.feat_requested = bs.feat_requested.max(pb.requested);
        bs.feat_misses = bs.feat_misses.max(pb.misses);
        bs.feat_fabric_rows = bs.feat_fabric_rows.max(pb.fabric);
        bs.total_requested += pb.requested;
        bs.total_misses += pb.misses;
        bs.samp_ms += pb.samp_ms;
        bs.feat_ms += pb.feat_ms;
    }
    if mode == Mode::Independent {
        let sum: usize = per_pe
            .iter()
            .filter_map(|p| p.input_vertices.as_ref().map(|v| v.len()))
            .sum();
        let mut union: Vec<VertexId> = per_pe
            .iter()
            .filter_map(|p| p.input_vertices.as_ref())
            .flat_map(|v| v.iter().copied())
            .collect();
        union.sort_unstable();
        union.dedup();
        if !union.is_empty() {
            bs.dup = sum as f64 / union.len() as f64;
        }
    }
    bs
}

/// Average the per-batch reductions into the report.
fn finalize(cfg: &EngineConfig, stats: &[BatchStats]) -> EngineReport {
    let layers = cfg.sampler.layers;
    let mut report = EngineReport {
        mode: cfg.mode.name().to_string(),
        num_pes: cfg.num_pes,
        s: vec![0.0; layers + 1],
        e: vec![0.0; layers],
        tilde: vec![0.0; layers],
        cross: vec![0.0; layers],
        dup_factor: 1.0,
        ..Default::default()
    };
    let m = stats.len().max(1) as f64;
    let mut total_hits = 0u64;
    let mut total_misses = 0u64;
    let mut dup_acc = 0.0;
    for bs in stats {
        for l in 0..=layers {
            report.s[l] += bs.s[l] as f64;
        }
        for l in 0..layers {
            report.e[l] += bs.e[l] as f64;
            report.tilde[l] += bs.tilde[l] as f64;
            report.cross[l] += bs.cross[l] as f64;
        }
        report.feat_requested += bs.feat_requested as f64;
        report.feat_misses += bs.feat_misses as f64;
        report.feat_fabric_rows += bs.feat_fabric_rows as f64;
        total_hits += bs.total_requested - bs.total_misses;
        total_misses += bs.total_misses;
        dup_acc += bs.dup;
        report.wall_sampling_ms += bs.samp_ms;
        report.wall_feature_ms += bs.feat_ms;
        report.wall_batch_ms += bs.wall_ms;
    }
    for v in report
        .s
        .iter_mut()
        .chain(report.e.iter_mut())
        .chain(report.tilde.iter_mut())
        .chain(report.cross.iter_mut())
    {
        *v /= m;
    }
    report.feat_requested /= m;
    report.feat_misses /= m;
    report.feat_fabric_rows /= m;
    report.wall_sampling_ms /= m;
    report.wall_feature_ms /= m;
    report.wall_batch_ms /= m;
    if cfg.mode == Mode::Independent {
        report.dup_factor = dup_acc / m;
    }
    report.cache_miss_rate = if total_hits + total_misses == 0 {
        0.0
    } else {
        total_misses as f64 / (total_hits + total_misses) as f64
    };
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{datasets, partition};
    use crate::sampling::Kappa;

    fn fixture() -> (Dataset, Partition) {
        let ds = datasets::build("tiny", 1).unwrap();
        let part = partition::random(&ds.graph, 4, 2);
        (ds, part)
    }

    fn small_cfg(mode: Mode) -> EngineConfig {
        EngineConfig {
            mode,
            num_pes: 4,
            batch_per_pe: 32,
            cache_per_pe: 200,
            warmup_batches: 2,
            measure_batches: 4,
            ..Default::default()
        }
    }

    #[test]
    fn indep_report_shape() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Independent));
        assert_eq!(r.s.len(), 4);
        assert_eq!(r.e.len(), 3);
        assert!(r.s[0] > 0.0 && r.s[3] >= r.s[1]);
        assert!(r.dup_factor >= 1.0);
        assert!(r.feat_requested > 0.0);
        assert!((0.0..=1.0).contains(&r.cache_miss_rate));
        assert!(r.wall_batch_ms >= 0.0);
    }

    #[test]
    fn coop_report_has_fabric_traffic() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Cooperative));
        assert!(r.tilde[0] > 0.0, "coop must record S̃ counts");
        assert!(r.cross[0] > 0.0, "random partition ⇒ cross traffic");
        assert!(r.feat_fabric_rows > 0.0);
    }

    #[test]
    fn coop_per_pe_work_less_than_indep_same_global_batch() {
        // The headline effect: with identical global batch size, coop's
        // per-PE deepest-layer work |S_p^L| (max) is below indep's |S^L|.
        let (ds, part) = fixture();
        let ri = run(&ds, &part, &small_cfg(Mode::Independent));
        let rc = run(&ds, &part, &small_cfg(Mode::Cooperative));
        let l = 3;
        assert!(
            rc.s[l] < ri.s[l],
            "coop per-PE work {} must beat indep {}",
            rc.s[l],
            ri.s[l]
        );
    }

    #[test]
    fn dependent_batches_reduce_miss_rate() {
        // κ=64 must reduce the LRU miss rate vs κ=1 (Figure 5 effect).
        let (ds, part) = fixture();
        let mut base = small_cfg(Mode::Independent);
        base.num_pes = 1;
        base.batch_per_pe = 64;
        base.cache_per_pe = 400;
        base.warmup_batches = 4;
        base.measure_batches = 12;
        // rebuild partition for 1 PE
        let part1 = partition::random(&ds.graph, 1, 3);
        let _ = part;
        let r1 = run(&ds, &part1, &base);
        let mut dep = base.clone();
        dep.sampler.kappa = Kappa::Finite(64);
        let r64 = run(&ds, &part1, &dep);
        assert!(
            r64.cache_miss_rate < r1.cache_miss_rate,
            "κ=64 miss {} must beat κ=1 miss {}",
            r64.cache_miss_rate,
            r1.cache_miss_rate
        );
    }

    /// Assert every count field of two reports is exactly equal (wall
    /// clocks excluded — those are the only legitimately nondeterministic
    /// fields).
    fn assert_counts_identical(a: &EngineReport, b: &EngineReport, ctx: &str) {
        assert_eq!(a.s, b.s, "{ctx}: S");
        assert_eq!(a.e, b.e, "{ctx}: E");
        assert_eq!(a.tilde, b.tilde, "{ctx}: S~");
        assert_eq!(a.cross, b.cross, "{ctx}: cross");
        assert_eq!(a.feat_requested, b.feat_requested, "{ctx}: requested");
        assert_eq!(a.feat_misses, b.feat_misses, "{ctx}: misses");
        assert_eq!(a.feat_fabric_rows, b.feat_fabric_rows, "{ctx}: fabric");
        assert_eq!(a.cache_miss_rate, b.cache_miss_rate, "{ctx}: miss rate");
        assert_eq!(a.dup_factor, b.dup_factor, "{ctx}: dup");
    }

    #[test]
    fn serial_and_threaded_reports_bit_identical() {
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut cs = small_cfg(mode);
            cs.exec = ExecMode::Serial;
            let mut ct = small_cfg(mode);
            ct.exec = ExecMode::Threaded;
            let a = run(&ds, &part, &cs);
            let b = run(&ds, &part, &ct);
            assert_counts_identical(&a, &b, mode.name());
        }
    }

    #[test]
    fn serial_and_threaded_identical_under_dependent_batches() {
        // the κ>1 smoothing path must stay deterministic per PE thread
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut cs = small_cfg(mode);
            cs.sampler.kappa = Kappa::Finite(16);
            cs.exec = ExecMode::Serial;
            let mut ct = cs.clone();
            ct.exec = ExecMode::Threaded;
            let a = run(&ds, &part, &cs);
            let b = run(&ds, &part, &ct);
            assert_counts_identical(&a, &b, &format!("{} kappa=16", mode.name()));
        }
    }

    #[test]
    fn threaded_run_is_self_deterministic() {
        let (ds, part) = fixture();
        let cfg = small_cfg(Mode::Cooperative);
        let a = run(&ds, &part, &cfg);
        let b = run(&ds, &part, &cfg);
        assert_counts_identical(&a, &b, "repeat threaded");
    }
}
