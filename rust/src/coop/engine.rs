//! Multi-batch measurement engine.
//!
//! Drives `warmup + measure` minibatches of either mode over a dataset
//! and aggregates the per-stage counts the paper's complexity model
//! (Table 1) consumes: per-layer vertex/edge/communication counts
//! (max-over-PE, averaged over batches), feature-cache traffic, and real
//! CPU wall-clock per stage. The repro harnesses for Tables 4–7 and
//! Figure 5 are thin wrappers around [`run`].

use super::cache::LruCache;
use super::coop_sampler::{partition_seeds, sample_cooperative};
use super::feature_loader::{load_cooperative, load_independent, FeatureTraffic};
use super::indep::sample_independent;
use crate::graph::{Dataset, Partition, VertexId};
use crate::sampling::{SamplerConfig, SamplerKind};
use crate::util::rng::Pcg64;
use crate::util::stats::Timer;

/// Minibatching mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Independent,
    Cooperative,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Independent => "Indep",
            Mode::Cooperative => "Coop",
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: Mode,
    pub num_pes: usize,
    /// per-PE batch size b (global batch = b · P).
    pub batch_per_pe: usize,
    pub kind: SamplerKind,
    pub sampler: SamplerConfig,
    /// LRU capacity per PE (vertex rows).
    pub cache_per_pe: usize,
    pub warmup_batches: usize,
    pub measure_batches: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: Mode::Independent,
            num_pes: 4,
            batch_per_pe: 1024,
            kind: SamplerKind::Labor0,
            sampler: SamplerConfig::default(),
            cache_per_pe: 100_000,
            warmup_batches: 4,
            measure_batches: 16,
            seed: 0xC001,
        }
    }
}

/// Aggregated per-stage counts (averages of per-batch max-over-PE).
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub mode: String,
    pub num_pes: usize,
    /// |S^l| per layer (len L+1; l=0 is the seed count).
    pub s: Vec<f64>,
    /// |E^l| per layer (len L).
    pub e: Vec<f64>,
    /// |S̃^{l+1}| per layer (coop; len L; 0 for indep).
    pub tilde: Vec<f64>,
    /// cross-PE portion c·|S̃^{l+1}| (coop; len L).
    pub cross: Vec<f64>,
    /// feature stage (per batch averages).
    pub feat_requested: f64,
    pub feat_misses: f64,
    pub feat_fabric_rows: f64,
    pub cache_miss_rate: f64,
    /// duplication factor at the deepest layer (indep only; 1.0 for coop).
    pub dup_factor: f64,
    /// measured CPU wall-clock (ms per batch, summed across PEs).
    pub wall_sampling_ms: f64,
    pub wall_feature_ms: f64,
}

/// Run the engine over `dataset` with partition `part` (required for
/// cooperative mode; independent mode uses it only to shard the training
/// set).
pub fn run(dataset: &Dataset, part: &Partition, cfg: &EngineConfig) -> EngineReport {
    assert_eq!(part.num_parts, cfg.num_pes, "partition/PE mismatch");
    let layers = cfg.sampler.layers;
    let g = &dataset.graph;

    // --- per-PE training shards --------------------------------------
    // Coop: PE p draws seeds from train ∩ V_p (Algorithm 1). Indep: the
    // training set is sharded round-robin (classic data parallelism).
    let shards: Vec<Vec<VertexId>> = match cfg.mode {
        Mode::Cooperative => {
            let mut by_owner: Vec<Vec<VertexId>> = vec![Vec::new(); cfg.num_pes];
            for &v in &dataset.train {
                by_owner[part.part_of(v)].push(v);
            }
            by_owner
        }
        Mode::Independent => {
            let mut shards: Vec<Vec<VertexId>> = vec![Vec::new(); cfg.num_pes];
            for (i, &v) in dataset.train.iter().enumerate() {
                shards[i % cfg.num_pes].push(v);
            }
            shards
        }
    };

    let mut samplers: Vec<_> =
        (0..cfg.num_pes).map(|_| cfg.sampler.build(cfg.kind, g, cfg.seed)).collect();
    let mut caches: Vec<LruCache> =
        (0..cfg.num_pes).map(|_| LruCache::new(cfg.cache_per_pe)).collect();
    let mut seed_rngs: Vec<Pcg64> =
        (0..cfg.num_pes).map(|p| Pcg64::new(cfg.seed ^ (p as u64 + 1) * 0x9E37)).collect();

    let mut report = EngineReport {
        mode: cfg.mode.name().to_string(),
        num_pes: cfg.num_pes,
        s: vec![0.0; layers + 1],
        e: vec![0.0; layers],
        tilde: vec![0.0; layers],
        cross: vec![0.0; layers],
        dup_factor: 1.0,
        ..Default::default()
    };
    let mut dup_acc = 0.0;
    let mut measured = 0usize;
    let mut total_hits = 0u64;
    let mut total_misses = 0u64;

    for batch in 0..(cfg.warmup_batches + cfg.measure_batches) {
        let measuring = batch >= cfg.warmup_batches;
        // draw per-PE seeds
        let per_pe_seeds: Vec<Vec<VertexId>> = shards
            .iter()
            .zip(seed_rngs.iter_mut())
            .map(|(shard, rng)| {
                let b = cfg.batch_per_pe.min(shard.len());
                rng.sample_distinct(shard.len(), b)
                    .into_iter()
                    .map(|i| shard[i as usize])
                    .collect()
            })
            .collect();

        let timer = Timer::start();
        let (inputs, traffic): (Vec<Vec<VertexId>>, FeatureTraffic) = match cfg.mode {
            Mode::Cooperative => {
                // sampling must see the per-PE *ownership* re-partition of
                // whatever seeds were drawn (identity here by construction)
                let flat: Vec<VertexId> = per_pe_seeds.iter().flatten().copied().collect();
                let per_pe = partition_seeds(&flat, part);
                let coop = sample_cooperative(g, part, &mut samplers, &per_pe, layers);
                let samp_ms = timer.elapsed_ms();
                if measuring {
                    for l in 0..layers {
                        report.s[l] += coop.max_owned(l) as f64;
                        report.e[l] += coop.max_edges(l) as f64;
                        report.tilde[l] += coop.max_tilde(l) as f64;
                        report.cross[l] += coop.max_cross(l) as f64;
                    }
                    report.s[layers] += coop.max_owned(layers) as f64;
                    report.wall_sampling_ms += samp_ms;
                }
                let fabric: Vec<u64> =
                    coop.layers[layers - 1].iter().map(|pl| pl.cross as u64).collect();
                let ft = Timer::start();
                let traffic = load_cooperative(&coop.final_owned, &fabric, &mut caches);
                if measuring {
                    report.wall_feature_ms += ft.elapsed_ms();
                }
                (coop.final_owned, traffic)
            }
            Mode::Independent => {
                let s = sample_independent(&mut samplers, &per_pe_seeds);
                let samp_ms = timer.elapsed_ms();
                if measuring {
                    for l in 0..layers {
                        report.s[l] += s.max_vertices(l) as f64;
                        report.e[l] += s.max_edges(l) as f64;
                    }
                    report.s[layers] += s.max_vertices(layers) as f64;
                    report.wall_sampling_ms += samp_ms;
                    dup_acc += s.duplication(layers);
                }
                let inputs: Vec<Vec<VertexId>> =
                    s.per_pe.iter().map(|m| m.input_vertices().to_vec()).collect();
                let ft = Timer::start();
                let traffic = load_independent(&inputs, &mut caches);
                if measuring {
                    report.wall_feature_ms += ft.elapsed_ms();
                }
                (inputs, traffic)
            }
        };
        let _ = inputs;
        if measuring {
            measured += 1;
            report.feat_requested += traffic.max_requested as f64;
            report.feat_misses += traffic.max_misses as f64;
            report.feat_fabric_rows += traffic.max_fabric_rows as f64;
            total_hits += traffic.total_requested - traffic.total_misses;
            total_misses += traffic.total_misses;
        }
        for s in samplers.iter_mut() {
            s.advance_batch();
        }
    }

    let m = measured.max(1) as f64;
    for v in report
        .s
        .iter_mut()
        .chain(report.e.iter_mut())
        .chain(report.tilde.iter_mut())
        .chain(report.cross.iter_mut())
    {
        *v /= m;
    }
    report.feat_requested /= m;
    report.feat_misses /= m;
    report.feat_fabric_rows /= m;
    report.wall_sampling_ms /= m;
    report.wall_feature_ms /= m;
    if cfg.mode == Mode::Independent {
        report.dup_factor = dup_acc / m;
    }
    report.cache_miss_rate = if total_hits + total_misses == 0 {
        0.0
    } else {
        total_misses as f64 / (total_hits + total_misses) as f64
    };
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{datasets, partition};
    use crate::sampling::Kappa;

    fn fixture() -> (Dataset, Partition) {
        let ds = datasets::build("tiny", 1).unwrap();
        let part = partition::random(&ds.graph, 4, 2);
        (ds, part)
    }

    fn small_cfg(mode: Mode) -> EngineConfig {
        EngineConfig {
            mode,
            num_pes: 4,
            batch_per_pe: 32,
            cache_per_pe: 200,
            warmup_batches: 2,
            measure_batches: 4,
            ..Default::default()
        }
    }

    #[test]
    fn indep_report_shape() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Independent));
        assert_eq!(r.s.len(), 4);
        assert_eq!(r.e.len(), 3);
        assert!(r.s[0] > 0.0 && r.s[3] >= r.s[1]);
        assert!(r.dup_factor >= 1.0);
        assert!(r.feat_requested > 0.0);
        assert!((0.0..=1.0).contains(&r.cache_miss_rate));
    }

    #[test]
    fn coop_report_has_fabric_traffic() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Cooperative));
        assert!(r.tilde[0] > 0.0, "coop must record S̃ counts");
        assert!(r.cross[0] > 0.0, "random partition ⇒ cross traffic");
        assert!(r.feat_fabric_rows > 0.0);
    }

    #[test]
    fn coop_per_pe_work_less_than_indep_same_global_batch() {
        // The headline effect: with identical global batch size, coop's
        // per-PE deepest-layer work |S_p^L| (max) is below indep's |S^L|.
        let (ds, part) = fixture();
        let ri = run(&ds, &part, &small_cfg(Mode::Independent));
        let rc = run(&ds, &part, &small_cfg(Mode::Cooperative));
        let l = 3;
        assert!(
            rc.s[l] < ri.s[l],
            "coop per-PE work {} must beat indep {}",
            rc.s[l],
            ri.s[l]
        );
    }

    #[test]
    fn dependent_batches_reduce_miss_rate() {
        // κ=64 must reduce the LRU miss rate vs κ=1 (Figure 5 effect).
        let (ds, part) = fixture();
        let mut base = small_cfg(Mode::Independent);
        base.num_pes = 1;
        base.batch_per_pe = 64;
        base.cache_per_pe = 400;
        base.warmup_batches = 4;
        base.measure_batches = 12;
        // rebuild partition for 1 PE
        let part1 = partition::random(&ds.graph, 1, 3);
        let _ = part;
        let r1 = run(&ds, &part1, &base);
        let mut dep = base.clone();
        dep.sampler.kappa = Kappa::Finite(64);
        let r64 = run(&ds, &part1, &dep);
        assert!(
            r64.cache_miss_rate < r1.cache_miss_rate,
            "κ=64 miss {} must beat κ=1 miss {}",
            r64.cache_miss_rate,
            r1.cache_miss_rate
        );
    }
}
