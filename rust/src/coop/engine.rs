//! Multi-batch measurement engine — a thin aggregator over the
//! pipeline's [`MinibatchStream`].
//!
//! [`run`] drains `warmup + measure` minibatches from a
//! [`crate::pipeline::EngineStream`] and reduces the per-PE work records
//! into the per-stage counts the paper's complexity model (Table 1)
//! consumes: per-layer vertex/edge/communication counts (max-over-PE,
//! averaged over batches), feature-cache traffic — both row counts and
//! the **measured bytes** behind them (storage β reads, fabric α
//! arrivals) — and real CPU wall-clock per stage. The repro harnesses
//! for Tables 4–7 and Figure 5 are thin wrappers around [`run`] via
//! [`crate::pipeline::Pipeline::engine_report`].
//!
//! ## Execution modes
//!
//! * [`ExecMode::Threaded`] (default) — **one OS thread per PE** (scoped
//!   threads, spawned per batch over state the stream persists between
//!   batches). Each PE owns its sampler, its seed RNG stream, and its
//!   LRU row cache; cooperative sampling exchanges ids — and cooperative
//!   loading exchanges feature-row payloads — over the live channel
//!   fabric ([`super::all_to_all::Fabric`]) with a barrier per
//!   all-to-all round. Sampling and feature loading of different PEs
//!   genuinely overlap: [`EngineReport::wall_batch_ms`] drops below the
//!   *serial* mode's batch wall-clock for the identical workload
//!   (`benches/bench_coop.rs` prints the comparison).
//! * [`ExecMode::Serial`] — the single-threaded reference (debugging
//!   fallback; CLI `--exec serial`).
//!
//! Orthogonally, [`EngineConfig::prefetch`] (CLI `--prefetch 1`)
//! double-buffers the stream: a producer thread samples + gathers batch
//! t+1 while the reduction consumes batch t
//! ([`crate::pipeline::with_prefetch`]).
//!
//! All modes are **bit-identical**: per-PE RNG streams are split from
//! the engine seed the same way, samplers share counter-based coins, and
//! per-batch statistics are reduced through one code path, so every
//! count field of the report matches exactly — across exec modes,
//! prefetch on/off, *and* against the PR-1 pre-stream engine loops,
//! which are preserved as a test oracle below. Only the wall-clock
//! fields differ.

use crate::graph::{Dataset, Partition, VertexId};
use crate::obs::{ms_to_us, split_dur, Span, Trace, TraceSink};
use crate::pipeline::{with_prefetch, EngineStream, MinibatchStream, PeWork};
use crate::sampling::{SamplerConfig, SamplerKind};

/// Minibatching mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Independent,
    Cooperative,
}

impl Mode {
    pub fn name(&self) -> &'static str {
        match self {
            Mode::Independent => "Indep",
            Mode::Cooperative => "Coop",
        }
    }

    pub fn parse(s: &str) -> Option<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "indep" | "independent" => Some(Mode::Independent),
            "coop" | "cooperative" => Some(Mode::Cooperative),
            _ => None,
        }
    }
}

/// How the engine schedules PE work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded reference loop (debugging fallback).
    Serial,
    /// One OS thread per PE with a live channel fabric (default).
    Threaded,
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Serial => "serial",
            ExecMode::Threaded => "threaded",
        }
    }

    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Some(ExecMode::Serial),
            "threaded" | "parallel" => Some(ExecMode::Threaded),
            _ => None,
        }
    }
}

/// Engine configuration (the lowered form of
/// [`crate::pipeline::PipelineConfig`], with the cache default already
/// resolved).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub mode: Mode,
    /// thread-per-PE or the serial reference loop.
    pub exec: ExecMode,
    /// double-buffer the stream behind a producer thread.
    pub prefetch: bool,
    pub num_pes: usize,
    /// replica-group size r (1 = flat fabric). Groups of r consecutive
    /// PEs each hold a full copy of the group's feature shards, so
    /// cooperative row requests resolve intra-group and only the
    /// first copy per remote group crosses the slow inter-group link.
    pub replication: usize,
    /// per-PE batch size b (global batch = b · P).
    pub batch_per_pe: usize,
    pub kind: SamplerKind,
    pub sampler: SamplerConfig,
    /// LRU capacity per PE (vertex rows).
    pub cache_per_pe: usize,
    pub warmup_batches: usize,
    pub measure_batches: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            mode: Mode::Independent,
            exec: ExecMode::Threaded,
            prefetch: false,
            num_pes: 4,
            replication: 1,
            batch_per_pe: 1024,
            kind: SamplerKind::Labor0,
            sampler: SamplerConfig::default(),
            cache_per_pe: 100_000,
            warmup_batches: 4,
            measure_batches: 16,
            seed: crate::pipeline::DEFAULT_SEED,
        }
    }
}

/// Aggregated per-stage counts (averages of per-batch max-over-PE).
#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub mode: String,
    pub num_pes: usize,
    /// |S^l| per layer (len L+1; l=0 is the seed count).
    pub s: Vec<f64>,
    /// |E^l| per layer (len L).
    pub e: Vec<f64>,
    /// |S̃^{l+1}| per layer (coop; len L; 0 for indep).
    pub tilde: Vec<f64>,
    /// cross-PE portion c·|S̃^{l+1}| (coop; len L).
    pub cross: Vec<f64>,
    /// feature stage (per batch averages).
    pub feat_requested: f64,
    pub feat_misses: f64,
    pub feat_fabric_rows: f64,
    pub cache_miss_rate: f64,
    /// f32 bytes copied from storage per batch (β; total across PEs,
    /// averaged over measured batches) — real movement, not a count
    /// model.
    pub feat_storage_bytes: f64,
    /// f32 bytes received over the fabric per batch (α; total across
    /// PEs, averaged over measured batches).
    pub feat_fabric_bytes: f64,
    /// the slice of `feat_fabric_bytes` that crossed a replica-group
    /// boundary (first-copy-per-group; equals `feat_fabric_bytes` at
    /// replication 1, shrinks ≈ r× under `--replication r`).
    pub feat_fabric_inter_bytes: f64,
    /// miss rate **derived from the byte movement**:
    /// Σ storage bytes / Σ requested bytes over the measured window
    /// (both in wire bytes of the active codec). With the default
    /// single-tier store this agrees with `cache_miss_rate` (which is
    /// counter-based) up to f64 rounding — the byte-accounting property
    /// test pins the underlying integers to each other exactly. A hot
    /// tier lowers it below `cache_miss_rate`: hot fills never touch
    /// storage, so their bytes drop out of the numerator.
    pub derived_miss_rate: f64,
    /// cache fills served by the hot tier (decoded rows in PE memory, γ)
    /// instead of cold storage, per batch (total across PEs, averaged).
    /// 0 unless the pipeline runs a [`crate::feature::TieredStore`].
    pub feat_hot_rows: f64,
    /// decoded f32 bytes those hot fills moved (γ traffic; the cold-tier
    /// complement is `feat_storage_bytes`, in *wire* bytes).
    pub feat_hot_bytes: f64,
    /// fraction of cache fills the hot tier absorbed:
    /// Σ hot rows / Σ misses over the measured window (0 when no tiering).
    pub hot_hit_rate: f64,
    /// rows promoted into the hot tier by the depth-1 costmodel prefetch
    /// seam, per batch (0 unless `--prefetch 1` *and* a tiered store).
    pub prefetch_rows: f64,
    /// wire bytes those promotions read from cold storage, per batch.
    pub prefetch_bytes: f64,
    /// duplication factor at the deepest layer (indep only; 1.0 for coop).
    pub dup_factor: f64,
    /// measured CPU stage time (ms per batch, **summed across PEs** —
    /// each PE's own elapsed sampling / feature-loading time; in
    /// threaded mode this includes time blocked in the exchange, so the
    /// sum over PEs is an upper bound on useful work).
    pub wall_sampling_ms: f64,
    pub wall_feature_ms: f64,
    /// wall-clock per batch (ms). Threaded mode: the real concurrent
    /// latency of the batch; compare against a `Serial` run of the same
    /// config for the concurrency speedup. Serial mode: ≈ the stage sum
    /// by construction.
    pub wall_batch_ms: f64,
}

impl EngineReport {
    /// Total cross-PE fabric bytes per batch across the engine's
    /// ledgers: sampled ids out + back (4 B each way per cross vertex —
    /// the [`crate::costmodel::estimate`] convention) plus the measured
    /// feature-row payloads. Report consumers print this instead of
    /// re-summing the columns ad hoc.
    pub fn total_cross_bytes(&self) -> f64 {
        let id_bytes: f64 = self.cross.iter().map(|c| c * 8.0).sum();
        id_bytes + self.feat_fabric_bytes
    }
}

/// Cross-PE reduction of one batch (max-over-PE counts, totals, dup,
/// measured bytes).
struct BatchStats {
    s: Vec<u64>,
    e: Vec<u64>,
    tilde: Vec<u64>,
    cross: Vec<u64>,
    feat_requested: u64,
    feat_misses: u64,
    feat_fabric_rows: u64,
    total_requested: u64,
    total_misses: u64,
    storage_bytes: u64,
    fabric_bytes: u64,
    fabric_inter_bytes: u64,
    requested_bytes: u64,
    hot_rows: u64,
    hot_bytes: u64,
    prefetch_rows: u64,
    prefetch_bytes: u64,
    dup: f64,
    samp_ms: f64,
    feat_ms: f64,
    wall_ms: f64,
}

/// Run the engine over `dataset` with partition `part` (required for
/// cooperative mode; independent mode uses it to shard the training set
/// and the feature store): build the measurement stream and drain it
/// (double-buffered when `cfg.prefetch`).
pub fn run(dataset: &Dataset, part: &Partition, cfg: &EngineConfig) -> EngineReport {
    run_stream(EngineStream::new(dataset, part, cfg), cfg)
}

/// Drain `stream` per `cfg`'s measurement window: inline, or (with
/// `cfg.prefetch`) moved onto a producer thread so batch t+1's
/// production overlaps batch t's reduction.
pub fn run_stream(mut stream: EngineStream<'_>, cfg: &EngineConfig) -> EngineReport {
    if cfg.prefetch {
        with_prefetch(stream, |s| drain(s, cfg))
    } else {
        drain(&mut stream, cfg)
    }
}

/// [`run_stream`] with a flight-recorder attached: measured batches
/// additionally emit per-PE stage spans into `trace` (see
/// [`drain_traced`]). With [`Trace::Off`] this is exactly `run_stream`.
pub fn run_stream_traced(
    mut stream: EngineStream<'_>,
    cfg: &EngineConfig,
    trace: &mut Trace,
) -> EngineReport {
    if cfg.prefetch {
        with_prefetch(stream, |s| drain_traced(s, cfg, trace))
    } else {
        drain_traced(&mut stream, cfg, trace)
    }
}

/// Drain `warmup + measure` batches from any stream and aggregate the
/// measured ones — the engine reduced to what it is: an aggregator.
///
/// Mode, layer count, and PE count come from the stream itself (the
/// only party that knows what it yields); `cfg` contributes only the
/// measurement window, so a stream whose shape disagrees with the
/// config that happened to build it cannot be mis-reduced.
pub fn drain(stream: &mut dyn MinibatchStream, cfg: &EngineConfig) -> EngineReport {
    drain_traced(stream, cfg, &mut Trace::Off)
}

/// [`drain`] with a flight-recorder attached: each **measured** batch
/// additionally derives per-PE stage spans (sample → cache_fill /
/// hot_fill / fabric_all_to_all, plus a prefetch marker) from the very
/// [`PeWork`] records the reduction consumes. Because spans are
/// derived *after* the batch from already-counted ledgers, the report
/// is bit-identical with tracing on or off, and per-stage span bytes
/// divided by the measured-batch count reconcile exactly with the
/// report's `feat_*` byte fields (pinned in
/// `tests/integration_obs.rs`).
pub fn drain_traced(
    stream: &mut dyn MinibatchStream,
    cfg: &EngineConfig,
    trace: &mut Trace,
) -> EngineReport {
    let layers = stream.layers();
    let mode = stream.mode();
    let num_pes = stream.num_pes();
    let mut stats: Vec<BatchStats> = Vec::with_capacity(cfg.measure_batches);
    let mut cursor = vec![0u64; num_pes];
    for batch in 0..(cfg.warmup_batches + cfg.measure_batches) {
        let mb = stream.next_batch();
        if batch >= cfg.warmup_batches {
            if trace.enabled() {
                let measured = (batch - cfg.warmup_batches) as u64;
                emit_batch_spans(trace, measured, &mb.per_pe, &mut cursor);
            }
            let mut bs = reduce(mode, layers, &mb.per_pe);
            bs.wall_ms = mb.wall_ms;
            stats.push(bs);
        }
    }
    // the window is drained: stop any background producer before the
    // final reduction instead of letting it sample batches nobody reads
    stream.finish();
    finalize(mode, num_pes, layers, &stats)
}

/// Derive one measured batch's spans from its per-PE work records.
///
/// Timeline model: all PEs start the batch together at the global max
/// of the previous batch's per-PE ends (the engine's per-batch
/// barrier). Each PE runs its sample stage (`samp_ms` → µs), then its
/// feature window (`feat_ms` → µs) split across `cache_fill` /
/// `hot_fill` / `fabric_all_to_all` proportionally to their byte
/// ledgers (largest-remainder, so the sub-spans tile the window
/// exactly). A zero-duration `prefetch` marker on the charged PE
/// carries the prefetch bytes. `seq` restarts per `(batch, pe)`, so
/// `(batch, pe, seq)` totally orders the merged span list.
pub(crate) fn emit_batch_spans(
    trace: &mut Trace,
    batch: u64,
    per_pe: &[PeWork],
    cursor: &mut [u64],
) {
    let base = cursor.iter().copied().max().unwrap_or(0);
    for (pe, w) in per_pe.iter().enumerate() {
        let mut seq = 0u32;
        let mut span = |stage, t0, t1, bytes| Span {
            batch,
            pe: pe as u32,
            seq: {
                let s = seq;
                seq += 1;
                s
            },
            stage,
            t_start_us: t0,
            t_end_us: t1,
            bytes,
        };
        let samp_us = ms_to_us(w.samp_ms);
        let feat_us = ms_to_us(w.feat_ms);
        let t_feat = base + samp_us;
        trace.record(span("sample", base, t_feat, 0));
        let parts = split_dur(
            feat_us,
            &[w.bytes_from_storage, w.hot_bytes, w.fabric_bytes],
        );
        let mut t = t_feat;
        for (stage, (dur, bytes)) in ["cache_fill", "hot_fill", "fabric_all_to_all"]
            .into_iter()
            .zip(
                parts
                    .iter()
                    .zip([w.bytes_from_storage, w.hot_bytes, w.fabric_bytes]),
            )
        {
            trace.record(span(stage, t, t + dur, bytes));
            t += dur;
        }
        if w.prefetch_bytes > 0 || w.prefetch_rows > 0 {
            trace.record(span("prefetch", base, base, w.prefetch_bytes));
        }
        cursor[pe] = t;
    }
}

/// Max/total reduction of one batch across PEs — one code path for
/// every exec mode and stream, so the aggregated numbers are
/// bit-identical by construction.
fn reduce(mode: Mode, layers: usize, per_pe: &[PeWork]) -> BatchStats {
    let mut bs = BatchStats {
        s: vec![0; layers + 1],
        e: vec![0; layers],
        tilde: vec![0; layers],
        cross: vec![0; layers],
        feat_requested: 0,
        feat_misses: 0,
        feat_fabric_rows: 0,
        total_requested: 0,
        total_misses: 0,
        storage_bytes: 0,
        fabric_bytes: 0,
        fabric_inter_bytes: 0,
        requested_bytes: 0,
        hot_rows: 0,
        hot_bytes: 0,
        prefetch_rows: 0,
        prefetch_bytes: 0,
        dup: 1.0,
        samp_ms: 0.0,
        feat_ms: 0.0,
        wall_ms: 0.0,
    };
    for pw in per_pe {
        for l in 0..=layers {
            bs.s[l] = bs.s[l].max(pw.counts_s[l]);
        }
        for l in 0..layers {
            bs.e[l] = bs.e[l].max(pw.counts_e[l]);
            bs.tilde[l] = bs.tilde[l].max(pw.counts_tilde[l]);
            bs.cross[l] = bs.cross[l].max(pw.counts_cross[l]);
        }
        bs.feat_requested = bs.feat_requested.max(pw.requested);
        bs.feat_misses = bs.feat_misses.max(pw.misses);
        bs.feat_fabric_rows = bs.feat_fabric_rows.max(pw.fabric);
        bs.total_requested += pw.requested;
        bs.total_misses += pw.misses;
        bs.storage_bytes += pw.bytes_from_storage;
        bs.fabric_bytes += pw.fabric_bytes;
        bs.fabric_inter_bytes += pw.fabric_inter_bytes;
        bs.requested_bytes += pw.requested * pw.row_bytes;
        bs.hot_rows += pw.hot_rows;
        bs.hot_bytes += pw.hot_bytes;
        bs.prefetch_rows += pw.prefetch_rows;
        bs.prefetch_bytes += pw.prefetch_bytes;
        bs.samp_ms += pw.samp_ms;
        bs.feat_ms += pw.feat_ms;
    }
    if mode == Mode::Independent {
        let sum: usize = per_pe
            .iter()
            .filter_map(|p| p.input_vertices.as_ref().map(|v| v.len()))
            .sum();
        let mut union: Vec<VertexId> = per_pe
            .iter()
            .filter_map(|p| p.input_vertices.as_ref())
            .flat_map(|v| v.iter().copied())
            .collect();
        union.sort_unstable();
        union.dedup();
        if !union.is_empty() {
            bs.dup = sum as f64 / union.len() as f64;
        }
    }
    bs
}

/// Average the per-batch reductions into the report.
fn finalize(mode: Mode, num_pes: usize, layers: usize, stats: &[BatchStats]) -> EngineReport {
    let mut report = EngineReport {
        mode: mode.name().to_string(),
        num_pes,
        s: vec![0.0; layers + 1],
        e: vec![0.0; layers],
        tilde: vec![0.0; layers],
        cross: vec![0.0; layers],
        dup_factor: 1.0,
        ..Default::default()
    };
    let m = stats.len().max(1) as f64;
    let mut total_hits = 0u64;
    let mut total_misses = 0u64;
    let mut total_storage_bytes = 0u64;
    let mut total_requested_bytes = 0u64;
    let mut total_hot_rows = 0u64;
    let mut dup_acc = 0.0;
    for bs in stats {
        for l in 0..=layers {
            report.s[l] += bs.s[l] as f64;
        }
        for l in 0..layers {
            report.e[l] += bs.e[l] as f64;
            report.tilde[l] += bs.tilde[l] as f64;
            report.cross[l] += bs.cross[l] as f64;
        }
        report.feat_requested += bs.feat_requested as f64;
        report.feat_misses += bs.feat_misses as f64;
        report.feat_fabric_rows += bs.feat_fabric_rows as f64;
        report.feat_storage_bytes += bs.storage_bytes as f64;
        report.feat_fabric_bytes += bs.fabric_bytes as f64;
        report.feat_fabric_inter_bytes += bs.fabric_inter_bytes as f64;
        report.feat_hot_rows += bs.hot_rows as f64;
        report.feat_hot_bytes += bs.hot_bytes as f64;
        report.prefetch_rows += bs.prefetch_rows as f64;
        report.prefetch_bytes += bs.prefetch_bytes as f64;
        total_hits += bs.total_requested - bs.total_misses;
        total_misses += bs.total_misses;
        total_storage_bytes += bs.storage_bytes;
        total_requested_bytes += bs.requested_bytes;
        total_hot_rows += bs.hot_rows;
        dup_acc += bs.dup;
        report.wall_sampling_ms += bs.samp_ms;
        report.wall_feature_ms += bs.feat_ms;
        report.wall_batch_ms += bs.wall_ms;
    }
    for v in report
        .s
        .iter_mut()
        .chain(report.e.iter_mut())
        .chain(report.tilde.iter_mut())
        .chain(report.cross.iter_mut())
    {
        *v /= m;
    }
    report.feat_requested /= m;
    report.feat_misses /= m;
    report.feat_fabric_rows /= m;
    report.feat_storage_bytes /= m;
    report.feat_fabric_bytes /= m;
    report.feat_fabric_inter_bytes /= m;
    report.feat_hot_rows /= m;
    report.feat_hot_bytes /= m;
    report.prefetch_rows /= m;
    report.prefetch_bytes /= m;
    report.wall_sampling_ms /= m;
    report.wall_feature_ms /= m;
    report.wall_batch_ms /= m;
    if mode == Mode::Independent {
        report.dup_factor = dup_acc / m;
    }
    report.cache_miss_rate = if total_hits + total_misses == 0 {
        0.0
    } else {
        total_misses as f64 / (total_hits + total_misses) as f64
    };
    report.derived_miss_rate = if total_requested_bytes == 0 {
        0.0
    } else {
        total_storage_bytes as f64 / total_requested_bytes as f64
    };
    report.hot_hit_rate = if total_misses == 0 {
        0.0
    } else {
        total_hot_rows as f64 / total_misses as f64
    };
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{datasets, partition};
    use crate::sampling::Kappa;

    fn fixture() -> (Dataset, Partition) {
        let ds = datasets::build("tiny", 1).unwrap();
        let part = partition::random(&ds.graph, 4, 2);
        (ds, part)
    }

    fn small_cfg(mode: Mode) -> EngineConfig {
        EngineConfig {
            mode,
            num_pes: 4,
            batch_per_pe: 32,
            cache_per_pe: 200,
            warmup_batches: 2,
            measure_batches: 4,
            ..Default::default()
        }
    }

    #[test]
    fn indep_report_shape() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Independent));
        assert_eq!(r.s.len(), 4);
        assert_eq!(r.e.len(), 3);
        assert!(r.s[0] > 0.0 && r.s[3] >= r.s[1]);
        assert!(r.dup_factor >= 1.0);
        assert!(r.feat_requested > 0.0);
        assert!((0.0..=1.0).contains(&r.cache_miss_rate));
        assert!((0.0..=1.0).contains(&r.derived_miss_rate));
        assert!(r.feat_storage_bytes > 0.0, "bytes must actually move");
        assert!(r.wall_batch_ms >= 0.0);
    }

    #[test]
    fn coop_report_has_fabric_traffic() {
        let (ds, part) = fixture();
        let r = run(&ds, &part, &small_cfg(Mode::Cooperative));
        assert!(r.tilde[0] > 0.0, "coop must record S̃ counts");
        assert!(r.cross[0] > 0.0, "random partition ⇒ cross traffic");
        assert!(r.feat_fabric_rows > 0.0);
        assert!(r.feat_fabric_bytes > 0.0, "fabric must carry row payloads");
    }

    #[test]
    fn byte_accounting_follows_counts() {
        // averages preserve the bytes-per-row relation: the per-batch
        // totals are integer multiples of row_bytes, so the averaged
        // report fields still satisfy bytes == rows * row_bytes
        let (ds, part) = fixture();
        let rb = ds.row_bytes() as f64;
        for mode in [Mode::Independent, Mode::Cooperative] {
            let r = run(&ds, &part, &small_cfg(mode));
            // max-over-PE misses and summed bytes are different
            // reductions, so compare rate-level quantities instead
            assert!(
                (r.derived_miss_rate - r.cache_miss_rate).abs() < 1e-12,
                "{mode:?}: byte-derived rate {} vs counter rate {}",
                r.derived_miss_rate,
                r.cache_miss_rate
            );
            if mode == Mode::Cooperative {
                // fabric rows are max-over-PE, fabric bytes total — both
                // positive and byte field divisible by row size
                let rows_from_bytes = r.feat_fabric_bytes / rb;
                assert!(rows_from_bytes >= r.feat_fabric_rows, "total >= max");
            }
        }
    }

    #[test]
    fn coop_per_pe_work_less_than_indep_same_global_batch() {
        // The headline effect: with identical global batch size, coop's
        // per-PE deepest-layer work |S_p^L| (max) is below indep's |S^L|.
        let (ds, part) = fixture();
        let ri = run(&ds, &part, &small_cfg(Mode::Independent));
        let rc = run(&ds, &part, &small_cfg(Mode::Cooperative));
        let l = 3;
        assert!(
            rc.s[l] < ri.s[l],
            "coop per-PE work {} must beat indep {}",
            rc.s[l],
            ri.s[l]
        );
    }

    #[test]
    fn dependent_batches_reduce_miss_rate() {
        // κ=64 must reduce the LRU miss rate vs κ=1 (Figure 5 effect).
        let (ds, part) = fixture();
        let mut base = small_cfg(Mode::Independent);
        base.num_pes = 1;
        base.batch_per_pe = 64;
        base.cache_per_pe = 400;
        base.warmup_batches = 4;
        base.measure_batches = 12;
        // rebuild partition for 1 PE
        let part1 = partition::random(&ds.graph, 1, 3);
        let _ = part;
        let r1 = run(&ds, &part1, &base);
        let mut dep = base.clone();
        dep.sampler.kappa = Kappa::Finite(64);
        let r64 = run(&ds, &part1, &dep);
        assert!(
            r64.cache_miss_rate < r1.cache_miss_rate,
            "κ=64 miss {} must beat κ=1 miss {}",
            r64.cache_miss_rate,
            r1.cache_miss_rate
        );
    }

    /// Assert every count field of two reports is exactly equal (wall
    /// clocks excluded — those are the only legitimately nondeterministic
    /// fields).
    fn assert_counts_identical(a: &EngineReport, b: &EngineReport, ctx: &str) {
        assert_eq!(a.s, b.s, "{ctx}: S");
        assert_eq!(a.e, b.e, "{ctx}: E");
        assert_eq!(a.tilde, b.tilde, "{ctx}: S~");
        assert_eq!(a.cross, b.cross, "{ctx}: cross");
        assert_eq!(a.feat_requested, b.feat_requested, "{ctx}: requested");
        assert_eq!(a.feat_misses, b.feat_misses, "{ctx}: misses");
        assert_eq!(a.feat_fabric_rows, b.feat_fabric_rows, "{ctx}: fabric");
        assert_eq!(a.cache_miss_rate, b.cache_miss_rate, "{ctx}: miss rate");
        assert_eq!(a.feat_storage_bytes, b.feat_storage_bytes, "{ctx}: storage bytes");
        assert_eq!(a.feat_fabric_bytes, b.feat_fabric_bytes, "{ctx}: fabric bytes");
        assert_eq!(a.feat_fabric_inter_bytes, b.feat_fabric_inter_bytes, "{ctx}: inter bytes");
        assert_eq!(a.derived_miss_rate, b.derived_miss_rate, "{ctx}: derived rate");
        assert_eq!(a.feat_hot_rows, b.feat_hot_rows, "{ctx}: hot rows");
        assert_eq!(a.feat_hot_bytes, b.feat_hot_bytes, "{ctx}: hot bytes");
        assert_eq!(a.hot_hit_rate, b.hot_hit_rate, "{ctx}: hot hit rate");
        assert_eq!(a.dup_factor, b.dup_factor, "{ctx}: dup");
    }

    #[test]
    fn serial_and_threaded_reports_bit_identical() {
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut cs = small_cfg(mode);
            cs.exec = ExecMode::Serial;
            let mut ct = small_cfg(mode);
            ct.exec = ExecMode::Threaded;
            let a = run(&ds, &part, &cs);
            let b = run(&ds, &part, &ct);
            assert_counts_identical(&a, &b, mode.name());
        }
    }

    #[test]
    fn serial_and_threaded_identical_under_dependent_batches() {
        // the κ>1 smoothing path must stay deterministic per PE thread
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut cs = small_cfg(mode);
            cs.sampler.kappa = Kappa::Finite(16);
            cs.exec = ExecMode::Serial;
            let mut ct = cs.clone();
            ct.exec = ExecMode::Threaded;
            let a = run(&ds, &part, &cs);
            let b = run(&ds, &part, &ct);
            assert_counts_identical(&a, &b, &format!("{} kappa=16", mode.name()));
        }
    }

    #[test]
    fn threaded_run_is_self_deterministic() {
        let (ds, part) = fixture();
        let cfg = small_cfg(Mode::Cooperative);
        let a = run(&ds, &part, &cfg);
        let b = run(&ds, &part, &cfg);
        assert_counts_identical(&a, &b, "repeat threaded");
    }

    #[test]
    fn prefetch_on_off_reports_bit_identical() {
        // the --prefetch determinism contract: double-buffering changes
        // when batches are produced, never what they contain
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            for exec in [ExecMode::Serial, ExecMode::Threaded] {
                let mut off = small_cfg(mode);
                off.exec = exec;
                let mut on = off.clone();
                on.prefetch = true;
                let a = run(&ds, &part, &off);
                let b = run(&ds, &part, &on);
                assert_counts_identical(
                    &a,
                    &b,
                    &format!("{}/{} prefetch", mode.name(), exec.name()),
                );
            }
        }
    }

    /// The PR-1 engine loops, preserved as the equivalence oracle for
    /// the stream redesign: the pre-stream serial batch loop and the
    /// pre-stream thread-per-*run* runtime (one long-lived OS thread per
    /// PE for the whole run, deposits reduced by PE 0 between barriers).
    /// The stream-based [`run`] must reproduce their counts bit-for-bit.
    /// (Feature-plane note: the oracle now loads rows through the same
    /// store/cache/fabric primitives — its *shape* is still the PR-1
    /// control flow, and every count it produces must match.)
    mod pr1_reference {
        use super::*;
        use crate::coop::all_to_all::{Exchange, Fabric};
        use crate::coop::cache::LruCache;
        use crate::coop::coop_sampler::{sample_cooperative, sample_cooperative_pe, PeLayer};
        use crate::coop::feature_loader::{load_cooperative, load_pe_cooperative};
        use crate::coop::indep::sample_independent;
        use crate::feature::{FeatureStore, PartitionedFeatureStore};
        use crate::pipeline::stream::{
            coop_pe_work, indep_pe_work, load_indep_pe, make_shards, pe_seed, AbortOnPeerPanic,
        };
        use crate::util::rng::Pcg64;
        use crate::util::stats::Timer;
        use std::sync::Mutex;

        pub fn run_pr1(dataset: &Dataset, part: &Partition, cfg: &EngineConfig) -> EngineReport {
            assert_eq!(part.num_parts, cfg.num_pes, "partition/PE mismatch");
            let shards = make_shards(dataset, part, cfg.mode, cfg.num_pes);
            let store = PartitionedFeatureStore::build(dataset, part);
            let stats = match cfg.exec {
                ExecMode::Serial => run_serial(dataset, part, cfg, &shards, &store),
                ExecMode::Threaded => run_threaded(dataset, part, cfg, &shards, &store),
            };
            finalize(cfg.mode, cfg.num_pes, cfg.sampler.layers, &stats)
        }

        fn run_serial(
            dataset: &Dataset,
            part: &Partition,
            cfg: &EngineConfig,
            shards: &[Vec<VertexId>],
            store: &PartitionedFeatureStore,
        ) -> Vec<BatchStats> {
            let g = &dataset.graph;
            let layers = cfg.sampler.layers;
            let p_count = cfg.num_pes;
            let dim = store.dim() as u64;
            let row_bytes = store.row_bytes() as u64;
            let mut samplers: Vec<_> =
                (0..p_count).map(|_| cfg.sampler.build(cfg.kind, g, cfg.seed)).collect();
            let mut caches: Vec<LruCache> = (0..p_count)
                .map(|_| LruCache::with_rows(cfg.cache_per_pe, dataset.feat_dim))
                .collect();
            let mut seed_rngs: Vec<Pcg64> =
                (0..p_count).map(|p| Pcg64::new(pe_seed(cfg.seed, p))).collect();
            let mut out: Vec<BatchStats> = Vec::with_capacity(cfg.measure_batches);

            for batch in 0..(cfg.warmup_batches + cfg.measure_batches) {
                let measuring = batch >= cfg.warmup_batches;
                let per_pe_seeds: Vec<Vec<VertexId>> = shards
                    .iter()
                    .zip(seed_rngs.iter_mut())
                    .map(|(shard, rng)| {
                        let b = cfg.batch_per_pe.min(shard.len());
                        rng.sample_distinct(shard.len(), b)
                            .into_iter()
                            .map(|i| shard[i as usize])
                            .collect()
                    })
                    .collect();

                let per_pe: Vec<_> = match cfg.mode {
                    Mode::Cooperative => {
                        let coop =
                            sample_cooperative(g, part, &mut samplers, &per_pe_seeds, layers);
                        let tildes: Vec<Vec<VertexId>> =
                            coop.layers[layers - 1].iter().map(|pl| pl.tilde.clone()).collect();
                        let mut row_fabric = Exchange::new(p_count);
                        let loads = load_cooperative(
                            &tildes,
                            &coop.final_requests,
                            &coop.final_owned,
                            part,
                            &mut caches,
                            store,
                            &mut row_fabric,
                        );
                        loads
                            .into_iter()
                            .enumerate()
                            .map(|(p, load)| {
                                let pe_layers: Vec<&PeLayer> =
                                    (0..layers).map(|l| &coop.layers[l][p]).collect();
                                coop_pe_work(layers, &pe_layers, dim, row_bytes, load)
                            })
                            .collect()
                    }
                    Mode::Independent => {
                        let s = sample_independent(&mut samplers, &per_pe_seeds);
                        s.per_pe
                            .iter()
                            .zip(caches.iter_mut())
                            .map(|(mfg, cache)| {
                                let load = load_indep_pe(mfg.input_vertices(), cache, store);
                                indep_pe_work(mfg, layers, measuring, dim, row_bytes, load)
                            })
                            .collect()
                    }
                };
                for s in samplers.iter_mut() {
                    s.advance_batch();
                }
                if measuring {
                    out.push(reduce(cfg.mode, layers, &per_pe));
                }
            }
            out
        }

        fn run_threaded(
            dataset: &Dataset,
            part: &Partition,
            cfg: &EngineConfig,
            shards: &[Vec<VertexId>],
            store: &PartitionedFeatureStore,
        ) -> Vec<BatchStats> {
            let g = &dataset.graph;
            let layers = cfg.sampler.layers;
            let p_count = cfg.num_pes;
            let dim = store.dim() as u64;
            let row_bytes = store.row_bytes() as u64;
            let total = cfg.warmup_batches + cfg.measure_batches;
            let barrier = std::sync::Barrier::new(p_count);
            let endpoints = Fabric::endpoints(p_count);
            let deposits: Vec<Mutex<Option<crate::pipeline::PeWork>>> =
                (0..p_count).map(|_| Mutex::new(None)).collect();
            let collected: Mutex<Vec<BatchStats>> =
                Mutex::new(Vec::with_capacity(cfg.measure_batches));

            std::thread::scope(|scope| {
                let barrier = &barrier;
                let deposits = &deposits;
                let collected = &collected;
                for (pe, mut ep) in endpoints.into_iter().enumerate() {
                    let shard = &shards[pe];
                    scope.spawn(move || {
                        let _abort_guard = AbortOnPeerPanic;
                        let mut sampler = cfg.sampler.build(cfg.kind, g, cfg.seed);
                        let mut cache = LruCache::with_rows(cfg.cache_per_pe, dataset.feat_dim);
                        let mut seed_rng = Pcg64::new(pe_seed(cfg.seed, pe));
                        for batch in 0..total {
                            let measuring = batch >= cfg.warmup_batches;
                            barrier.wait();
                            let wall = Timer::start();
                            let b = cfg.batch_per_pe.min(shard.len());
                            let seeds: Vec<VertexId> = seed_rng
                                .sample_distinct(shard.len(), b)
                                .into_iter()
                                .map(|i| shard[i as usize])
                                .collect();
                            let pw = match cfg.mode {
                                Mode::Cooperative => {
                                    let ps = sample_cooperative_pe(
                                        g, part, &mut sampler, &mut ep, seeds, layers,
                                    );
                                    let load = load_pe_cooperative(
                                        &mut ep,
                                        part,
                                        &ps.layers[layers - 1].tilde,
                                        &ps.final_owned,
                                        &ps.final_requests,
                                        &mut cache,
                                        store,
                                    );
                                    let pe_layers: Vec<&PeLayer> = ps.layers.iter().collect();
                                    coop_pe_work(layers, &pe_layers, dim, row_bytes, load)
                                }
                                Mode::Independent => {
                                    let mfg = sampler.sample_mfg(&seeds);
                                    let load =
                                        load_indep_pe(mfg.input_vertices(), &mut cache, store);
                                    indep_pe_work(&mfg, layers, measuring, dim, row_bytes, load)
                                }
                            };
                            sampler.advance_batch();
                            if measuring {
                                *deposits[pe].lock().unwrap() = Some(pw);
                            }
                            barrier.wait();
                            let wall_ms = wall.elapsed_ms();
                            if pe == 0 && measuring {
                                let per_pe: Vec<crate::pipeline::PeWork> = deposits
                                    .iter()
                                    .map(|d| d.lock().unwrap().take().expect("missing PE deposit"))
                                    .collect();
                                let mut bs = reduce(cfg.mode, layers, &per_pe);
                                bs.wall_ms = wall_ms;
                                collected.lock().unwrap().push(bs);
                            }
                        }
                    });
                }
            });
            collected.into_inner().unwrap()
        }
    }

    #[test]
    fn stream_engine_matches_pr1_reference() {
        // API-equivalence contract of the pipeline redesign: for both
        // modes × both exec modes, the stream-drained report is
        // bit-identical to the PR-1 engine loops at a fixed seed.
        let (ds, part) = fixture();
        for mode in [Mode::Independent, Mode::Cooperative] {
            for exec in [ExecMode::Serial, ExecMode::Threaded] {
                let mut cfg = small_cfg(mode);
                cfg.exec = exec;
                let new = run(&ds, &part, &cfg);
                let old = pr1_reference::run_pr1(&ds, &part, &cfg);
                assert_counts_identical(&new, &old, &format!("{}/{}", mode.name(), exec.name()));
            }
        }
    }
}
