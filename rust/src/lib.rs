//! # coopgnn — Cooperative Minibatching in Graph Neural Networks
//!
//! A three-layer Rust + JAX + Pallas reproduction of *Cooperative
//! Minibatching in Graph Neural Networks* (Balın, LaSalle, Çatalyürek, 2023).
//!
//! The crate is the **Layer-3 coordinator**: it owns the graph store, the
//! graph samplers (NS / LABOR-0 / LABOR-* / RW), the multi-PE cooperative
//! minibatching engine (Algorithm 1 of the paper), the dependent-minibatch
//! RNG (Appendix A.7), the LRU vertex-embedding cache, the training loop,
//! and the bandwidth cost model used to reproduce the paper's runtime
//! tables.
//!
//! ## Truly parallel cooperative engine
//!
//! The cooperative engine is **no longer a simulation**: by default it
//! spawns one OS thread per PE (scoped threads), gives each PE its own
//! deterministic RNG stream split from the engine seed, and runs the
//! all-to-all id redistribution of Algorithm 1 as real channel-based
//! message exchange with a barrier per round
//! ([`coop::engine::ExecMode::Threaded`]). Per-PE LRU caches live behind
//! their thread boundaries. A bit-identical single-threaded fallback
//! remains for debugging: set [`coop::engine::ExecMode::Serial`] on
//! [`coop::engine::EngineConfig::exec`] (CLI: `--exec serial`); the
//! determinism tests in `coop::engine` and `tests/integration_coop.rs`
//! assert that every count field of the [`coop::engine::EngineReport`]
//! matches across modes.
//!
//! Model forward/backward (Layer 2, JAX) and the aggregation kernels
//! (Layer 1, Pallas) are AOT-compiled to HLO text by
//! `python/compile/aot.py` and executed from Rust through PJRT
//! (`runtime` module); Python is never on the training path. This build
//! ships a host-side stub for the PJRT client (the offline toolchain
//! cannot vendor the `xla` crate — see `runtime::client`), so train/eval
//! paths report "runtime unavailable" while sampling, the engine, and the
//! count-based repro harnesses run natively.
//!
//! ## Quick tour
//!
//! ```no_run
//! use coopgnn::graph::datasets;
//! use coopgnn::sampling::{SamplerKind, SamplerConfig};
//!
//! // Build a synthetic dataset mirroring the paper's `flickr` traits.
//! let ds = datasets::build("flickr-s", 1).unwrap();
//! let cfg = SamplerConfig { fanout: 10, layers: 3, ..Default::default() };
//! let mut sampler = cfg.build(SamplerKind::Labor0, &ds.graph, 1234);
//! let mfg = sampler.sample_mfg(&[0, 1, 2, 3]);
//! assert_eq!(mfg.seeds().len(), 4);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a harness in [`repro`].

pub mod util;
pub mod graph;
pub mod sampling;
pub mod coop;
pub mod costmodel;
pub mod metrics;
pub mod runtime;
pub mod train;
pub mod repro;

/// Crate-wide result alias (anyhow is the only non-xla dependency).
pub type Result<T> = anyhow::Result<T>;
