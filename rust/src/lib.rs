//! # coopgnn — Cooperative Minibatching in Graph Neural Networks
//!
//! A three-layer Rust + JAX + Pallas reproduction of *Cooperative
//! Minibatching in Graph Neural Networks* (Balın, LaSalle, Çatalyürek, 2023).
//!
//! The crate is the **Layer-3 coordinator**: it owns the graph store, the
//! graph samplers (NS / LABOR-0 / LABOR-* / RW), the multi-PE cooperative
//! minibatching engine (Algorithm 1 of the paper), the dependent-minibatch
//! RNG (Appendix A.7), the LRU vertex-embedding cache, the training loop,
//! and the bandwidth cost model used to reproduce the paper's runtime
//! tables. Model forward/backward (Layer 2, JAX) and the aggregation
//! kernels (Layer 1, Pallas) are AOT-compiled to HLO text by
//! `python/compile/aot.py` and executed from Rust through PJRT
//! (`runtime` module); Python is never on the training path.
//!
//! ## Quick tour
//!
//! ```no_run
//! use coopgnn::graph::datasets;
//! use coopgnn::sampling::{SamplerKind, SamplerConfig};
//!
//! // Build a synthetic dataset mirroring the paper's `flickr` traits.
//! let ds = datasets::build("flickr-s", 1).unwrap();
//! let cfg = SamplerConfig { fanout: 10, layers: 3, ..Default::default() };
//! let mut sampler = cfg.build(SamplerKind::Labor0, &ds.graph, 1234);
//! let mfg = sampler.sample_mfg(&[0, 1, 2, 3]);
//! assert_eq!(mfg.seeds().len(), 4);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a harness in [`repro`].

pub mod util;
pub mod graph;
pub mod sampling;
pub mod coop;
pub mod costmodel;
pub mod metrics;
pub mod runtime;
pub mod train;
pub mod repro;

/// Crate-wide result alias (anyhow is the only non-xla dependency).
pub type Result<T> = anyhow::Result<T>;
