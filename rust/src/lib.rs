//! # coopgnn — Cooperative Minibatching in Graph Neural Networks
//!
//! A three-layer Rust + JAX + Pallas reproduction of *Cooperative
//! Minibatching in Graph Neural Networks* (Balın, LaSalle, Çatalyürek, 2023).
//!
//! The crate is the **Layer-3 coordinator**: it owns the graph store, the
//! graph samplers (NS / LABOR-0 / LABOR-* / RW), the multi-PE cooperative
//! minibatching engine (Algorithm 1 of the paper), the dependent-minibatch
//! RNG (Appendix A.7), the partitioned vertex-embedding store + per-PE LRU
//! row caches ([`feature`], [`coop::cache`]), the training loop, and the
//! bandwidth cost model used to reproduce the paper's runtime tables.
//!
//! ## A real feature plane
//!
//! Feature loading moves **actual bytes**: vertex rows are materialized
//! once into a [`feature::PartitionedFeatureStore`] (one shard per PE),
//! cache misses copy rows out of storage (β bandwidth), cooperative
//! loading ships rows between PEs over the channel fabric (α bandwidth,
//! [`coop::all_to_all::PeEndpoint::all_to_all_rows`]), and every
//! [`coop::engine::EngineReport`] traffic figure is derived from that
//! movement. `--prefetch 1` ([`pipeline::with_prefetch`]) double-buffers
//! the stream so batch t+1's sampling + gathering overlaps batch t's
//! compute.
//!
//! ## The storage plane: tiered compressed rows
//!
//! The bytes themselves are codec-shaped: rows are encoded **once** at
//! build time by a [`feature::Codec`] (`f32` passthrough, `fp16`
//! round-to-nearest-even, `int8` per-row scale/zero-point — wire sizes
//! `dim·4` / `dim·2` / `dim+5`) and decoded on gather, so every byte
//! ledger charges the wire size while consumers always see f32.
//! [`feature::TieredStore`] layers a capacity-bounded **hot tier** of
//! decoded top-degree rows (γ reads, `--hot-mb N`) over the compressed
//! cold shards (β reads), with a costmodel-budgeted prefetch annex that
//! promotes the exactly-predicted next batch's seed rows
//! ([`costmodel::default_prefetch_row_budget`]). The fabric ships the
//! stored encoding and decodes at the consumer, and the per-PE LRU
//! arenas stay encoded — compression multiplies effective cache
//! capacity. The f32/untiered default is pinned bit-identical to the
//! legacy store in `tests/integration_storage.rs`.
//!
//! ## The fabric plane: replica groups and topology-aware collectives
//!
//! The fabric is topology-aware: a [`coop::all_to_all::Topology`]
//! partitions the PEs into replica groups of `r` consecutive PEs
//! (`--replication r`), with fast intra-group links and slow
//! inter-group links priced per class by [`costmodel::FabricModel`]
//! (`--intra-bw` / `--inter-bw`). Every cross-PE ledger — ids, feature
//! rows, activations, gradients — splits into a total and an `inter_*`
//! group-boundary column. Under replication, each group holds a replica
//! of its members' shards (r× shard memory), so feature rows resolve
//! inside the local group, duplicate row sends into a remote group
//! cross the boundary once ([`coop::all_to_all::split_send_rows`]), and
//! the gradient all-reduce runs hierarchically (intra-group reduce,
//! leader chain, intra-group fan-out) — **bit-identical** to the flat
//! canonical sum, with inter-group bytes per phase shrinking from
//! `(P−1)` to `(P/r−1)` payloads. [`costmodel::pick_collective`]
//! chooses among [`coop::all_to_all::AllReduceStrategy`]'s
//! naive/tree/ring/rsag from the alpha-beta link model (`--allreduce
//! auto`), and `repro end2end --replication r` emits the per-r
//! inter-group byte table at pinned-identical training trajectories.
//!
//! ## One pipeline behind everything
//!
//! The public API is organized around [`pipeline`]: a typed
//! [`pipeline::PipelineConfig`] / [`pipeline::PipelineBuilder`] (one
//! validated description of a run, one seed default —
//! [`pipeline::DEFAULT_SEED`]) and the [`pipeline::MinibatchStream`]
//! trait (`next_batch()` → per-PE MFG work + feature/fabric traffic).
//! The CLI subcommands, the repro harnesses, the benches, and the
//! examples are all thin consumers of that one seam:
//!
//! * [`coop::engine::run`] drains a [`pipeline::EngineStream`] into an
//!   [`coop::engine::EngineReport`] (the count/traffic aggregates behind
//!   Tables 4–7 and Figure 5);
//! * [`train::Trainer`] executes batches pulled from a
//!   [`pipeline::TrainStream`] (shared-coin global batches, or merged
//!   independent sub-batches — the Figure 9 arms);
//! * [`train::ParallelTrainer`] is the **multi-PE training plane**: one
//!   trainer replica per PE over an [`pipeline::EngineStream`], kept in
//!   bit-identical lockstep by a gradient all-reduce on the fabric
//!   ([`coop::all_to_all::PeEndpoint::all_reduce_f32`];
//!   naive/tree/ring/rsag or costmodel-picked via `--allreduce auto`,
//!   hierarchical under `--replication`) — `repro end2end` and
//!   `train --train-pes N` run through it, natively in this build;
//! * κ > 1 dependent minibatching is a [`sampling::Kappa`] knob on the
//!   same streams;
//! * [`serve`] is the **online inference serving plane**: a virtual-time
//!   (integer-µs, bit-reproducible) request simulator whose SLO-aware
//!   dynamic batcher admits arrivals into cooperative engine batches via
//!   [`pipeline::EngineStream::batch_for_seeds`], with per-PE caches
//!   staying warm *across* request batches — `serve` on the CLI,
//!   `repro serve` for the indep/coop × fixed/adaptive matrix.
//!
//! ## Truly parallel cooperative engine
//!
//! The cooperative stream runs **one OS thread per PE**
//! ([`coop::engine::ExecMode::Threaded`], the default): each PE owns its
//! sampler, a deterministic RNG stream split from the engine seed, and
//! its LRU cache, and the all-to-all id redistribution of Algorithm 1 is
//! real channel-based message exchange with a barrier per round
//! ([`coop::all_to_all::Fabric`]). A bit-identical single-threaded
//! fallback remains for debugging ([`coop::engine::ExecMode::Serial`],
//! CLI `--exec serial`); determinism tests in `coop::engine` and
//! `tests/integration_coop.rs` assert that every count field of the
//! report matches across exec modes *and* against the preserved PR-1
//! engine loops.
//!
//! ## The compute plane: one model API, two backends
//!
//! All GNN compute — single-PE training, the multi-PE plane, evaluation
//! and serving predictions — runs layered gather→aggregate→matmul
//! through the [`model::GnnModel`] trait. The default backend is
//! [`model::HostModel`]: plain-Rust f32 kernels ([`model::kernels`])
//! numerically mirroring `python/compile/model.py` (golden-vector
//! parity is pinned in `tests/golden_model.rs`), with a per-PE step
//! engine ([`model::host::PeStep`]) that exchanges hidden activations
//! over the fabric in cooperative mode. Forward-only consumers hold a
//! [`model::Predictor`] parameter snapshot.
//!
//! The second backend is the PJRT/AOT bridge ([`model::PjrtModel`]):
//! model forward/backward (Layer 2, JAX) and the aggregation kernels
//! (Layer 1, Pallas) AOT-compiled to HLO text by
//! `python/compile/aot.py` and executed through PJRT (`runtime`
//! module); Python is never on the training path. This build ships a
//! host-side stub for the PJRT client (the offline toolchain cannot
//! vendor the `xla` crate — see `runtime::client`), so the PJRT backend
//! reports "runtime unavailable" while the host backend, sampling, the
//! engine, and the repro harnesses run natively.
//!
//! ## Quick tour
//!
//! ```no_run
//! use coopgnn::coop::engine::Mode;
//! use coopgnn::pipeline::PipelineBuilder;
//! use coopgnn::sampling::Kappa;
//!
//! // One builder call stands up dataset + partition + streams.
//! let pipe = PipelineBuilder::new()
//!     .dataset("flickr-s")       // synthetic twin of the paper's flickr
//!     .mode(Mode::Cooperative)   // vs Mode::Independent
//!     .num_pes(4)
//!     .batch_per_pe(1024)
//!     .kappa(Kappa::Finite(64))  // dependent minibatching (§3.2)
//!     .build()
//!     .unwrap();
//! let report = pipe.engine_report();
//! println!("per-PE |S^3| = {:.0}, miss rate {:.3}", report.s[3], report.cache_miss_rate);
//! ```
//!
//! ## The observability plane
//!
//! [`obs`] is the flight recorder every other plane reports through:
//! `--trace out.json` on `engine` / `train` / `serve` derives
//! `(batch, pe, stage, t_start, t_end, bytes)` spans **post-hoc from
//! the ledgers** ([`pipeline::PeWork`], the serve
//! [`serve::report::Ledger`]) and exports Chrome/Perfetto trace-event
//! JSON; `--metrics-out metrics.prom` writes a Prometheus-style text
//! exposition from the unified [`obs::Registry`] (the old `metrics`
//! bag, folded in). The contract: tracing off is zero-overhead, every
//! counter is bit-identical with tracing on vs off, serve traces are
//! bit-identical across exec modes and prefetch, and per-stage span
//! bytes reconcile exactly with the report ledgers
//! (`tests/integration_obs.rs`). [`obs::LEDGER_STRUCTS`] is the single
//! registry of lint-tracked counter structs — `coopgnn-lint`'s
//! `ledger` rule parses its struct list from that declaration, and
//! [`obs::LogHist`] stage histograms back the p50/p99 columns in
//! `repro end2end` / `repro serve`.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a harness in [`repro`].

// The whole crate is safe Rust: the threaded engine uses scoped threads
// and channels, the stores are plain Vecs. Enforced so the nightly
// Miri/TSan CI jobs stay meaningful (and cheap to reason about).
#![forbid(unsafe_code)]

pub mod util;
pub mod graph;
pub mod feature;
pub mod sampling;
pub mod coop;
pub mod pipeline;
pub mod costmodel;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod train;
pub mod serve;
pub mod repro;

/// Crate-wide result alias (anyhow is the only non-xla dependency).
pub type Result<T> = anyhow::Result<T>;
