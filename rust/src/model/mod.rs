//! The unified GNN compute plane — one model API over two backends.
//!
//! Every training/serving path in the repo runs layered
//! gather→aggregate→matmul compute through the [`GnnModel`] trait:
//!
//! * [`host::HostModel`] — the default backend: plain-Rust f32 kernels
//!   ([`kernels`]) over CSR [`HostBlock`]s, numerically mirroring
//!   `python/compile/model.py` (same layer recursion, masked
//!   cross-entropy, bias-corrected Adam). Runs everywhere, needs no
//!   artifacts, and is the reference the golden-vector parity test pins
//!   against the Python model.
//! * [`pjrt::PjrtModel`] — the AOT/PJRT bridge: the same contract
//!   routed through compiled train/forward executables and padded
//!   fixed-shape batches. A drop-in replacement behind the same trait
//!   wherever real PJRT artifacts are available.
//!
//! [`ModelDims`] mirrors Python's `ModelDims` named tuple and derives
//! the exact parameter shapes ([`ModelDims::param_shapes`]) of the flat
//! AOT calling convention, so a
//! [`crate::runtime::tensors::ParamState`] initialized from them is
//! interchangeable between backends.
//!
//! For the multi-PE plane, [`PeCompute`] carries a PE's private layered
//! blocks (plus [`CoopRoutes`] in cooperative mode: where to fetch
//! hidden activations from and which owned rows to serve), built by the
//! pipeline stream alongside sampling. [`Predictor`] is a cheap
//! parameter snapshot for forward-only consumers (evaluation, the
//! serving plane).

pub mod host;
pub mod kernels;
pub mod pjrt;

pub use host::HostModel;
pub use pjrt::PjrtModel;

use crate::graph::VertexId;
use crate::runtime::tensors::ParamState;
use crate::sampling::Mfg;
use std::sync::Arc;

/// Model hyper-shape, mirroring `python/compile/model.py::ModelDims`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// GNN layer count L (== sampled MFG depth).
    pub layers: usize,
    /// Input feature dimension.
    pub d_in: usize,
    /// Hidden width of every non-output layer.
    pub hidden: usize,
    /// Output classes.
    pub classes: usize,
}

impl ModelDims {
    /// Ordered parameter shapes `[w0, b0, w1, b1, …]`, input-first —
    /// exactly Python's `param_shapes` (the flat AOT calling
    /// convention), so [`ParamState::with_shapes`] seeds both backends
    /// identically.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = Vec::with_capacity(2 * self.layers);
        let mut d_prev = self.d_in;
        for l in 0..self.layers {
            let d_out = if l == self.layers - 1 { self.classes } else { self.hidden };
            shapes.push(vec![d_prev, d_out]);
            shapes.push(vec![d_out]);
            d_prev = d_out;
        }
        shapes
    }

    /// Input dimension of block `l` (block 0 = output layer, block L-1
    /// consumes raw features — Python's deepest-first recursion).
    pub fn in_dim(&self, l: usize) -> usize {
        if l == self.layers - 1 {
            self.d_in
        } else {
            self.hidden
        }
    }

    /// Output dimension of block `l`.
    pub fn out_dim(&self, l: usize) -> usize {
        if l == 0 {
            self.classes
        } else {
            self.hidden
        }
    }

    /// Parameter depth of block `l`: params `[2d, 2d+1]` with
    /// `d = L-1-l` (blocks count from the output, params from the
    /// input).
    pub fn depth_of(&self, l: usize) -> usize {
        self.layers - 1 - l
    }

    /// Total scalar parameter count.
    pub fn num_scalars(&self) -> usize {
        self.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// A [`ParamState`] Glorot-seeded for these shapes.
    pub fn init_state(&self, seed: u64) -> ParamState {
        ParamState::with_shapes(self.param_shapes(), seed)
    }
}

/// One bipartite layer of an MFG in host CSR form with explicit
/// aggregation weights — the host twin of the padded
/// `(nbr_idx, nbr_w, self_idx, self_w)` block tensors, without the
/// fixed-shape padding. Destination row `i` aggregates
/// `Σ_e nbr_w[e]·src[nbr_pos[e]] + self_w[i]·src[self_pos[i]]`.
#[derive(Clone, Debug, Default)]
pub struct HostBlock {
    pub n_dst: usize,
    pub n_src: usize,
    /// `[n_dst+1]` CSR offsets into `nbr_pos` / `nbr_w`.
    pub offsets: Vec<u32>,
    /// Sampled-neighbor positions in the source row space.
    pub nbr_pos: Vec<u32>,
    /// Per-edge mean weights (`1/(deg+1)`), matching `Mfg::pad`.
    pub nbr_w: Vec<f32>,
    /// `[n_dst]` own-row position in the source row space.
    pub self_pos: Vec<u32>,
    /// `[n_dst]` self weight (`1/(deg+1)`).
    pub self_w: Vec<f32>,
}

impl HostBlock {
    pub fn num_edges(&self) -> usize {
        self.nbr_pos.len()
    }

    /// Build block `l` of an [`Mfg`] (dst = layer l, src = layer l+1)
    /// with the same `1/(deg+1)` mean weights `Mfg::pad` would emit —
    /// but uncapped: the host plane has no fixed-shape truncation.
    pub fn from_mfg_layer(mfg: &Mfg, l: usize) -> HostBlock {
        let edges = &mfg.layer_edges[l];
        let n_dst = mfg.layer_vertices[l].len();
        let n_src = mfg.layer_vertices[l + 1].len();
        let mut b = HostBlock {
            n_dst,
            n_src,
            offsets: edges.offsets.clone(),
            nbr_pos: edges.nbr_local.clone(),
            nbr_w: vec![0f32; edges.num_edges()],
            self_pos: Vec::with_capacity(n_dst),
            self_w: Vec::with_capacity(n_dst),
        };
        for i in 0..n_dst {
            let deg = edges.of(i).len();
            let inv = 1.0 / (deg as f32 + 1.0);
            for e in edges.offsets[i] as usize..edges.offsets[i + 1] as usize {
                b.nbr_w[e] = inv;
            }
            let pos = match &mfg.self_pos {
                Some(sp) => sp[l][i],
                None => i as u32,
            };
            b.self_pos.push(pos);
            b.self_w.push(inv);
        }
        b
    }
}

/// All L blocks of an MFG, deepest source = the feature buffer.
pub fn blocks_from_mfg(mfg: &Mfg) -> Vec<HostBlock> {
    (0..mfg.num_layers()).map(|l| HostBlock::from_mfg_layer(mfg, l)).collect()
}

/// Activation-exchange routing for one PE's cooperative layered step.
/// Present only in cooperative mode; independent PEs compute without
/// fabric rounds. Indices are positions, never global ids, so the step
/// never needs the partition.
#[derive(Clone, Debug, Default)]
pub struct CoopRoutes {
    /// `recv_src[l][i]` = owner PE of this PE's block-`l` source row `i`
    /// (its Ṡ^l order) — the per-owner interleave the requester uses to
    /// reassemble its dense source buffer, for `l` in `0..L-1`.
    pub recv_src: Vec<Vec<u32>>,
    /// `send_pos[l][q]` = row positions into this PE's level-(l+1)
    /// activation buffer (rows over its owned S_p^{l+1}) to ship
    /// requester `q`, in `q`'s request order.
    pub send_pos: Vec<Vec<Vec<u32>>>,
}

/// One PE's layered compute payload, attached to a
/// `pipeline::PeWork` by the stream: the private MFG in host-block
/// form plus (cooperative mode) the activation routes. The source row
/// space of `blocks[L-1]` is exactly the PE's loaded feature buffer.
#[derive(Clone, Debug, Default)]
pub struct PeCompute {
    /// Per-layer blocks, index 0 = output layer.
    pub blocks: Vec<HostBlock>,
    /// Seed vertex ids (= dst rows of `blocks[0]`), for label lookup
    /// and prediction routing.
    pub seeds: Vec<VertexId>,
    /// Cooperative activation routes; `None` for independent batches.
    pub routes: Option<CoopRoutes>,
}

/// Metrics of one train step through a [`GnnModel`] backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainMetrics {
    pub loss: f32,
    /// Correct seed predictions (pre-update parameters).
    pub correct: f32,
    pub examples: f32,
    /// Host-side batch marshalling (block build / padding) ms.
    pub pad_ms: f64,
    /// Compute (kernel or PJRT execution) ms.
    pub exec_ms: f64,
    /// Fixed-shape cap truncation (always 0 on the host backend).
    pub truncated_vertices: usize,
    pub truncated_edges: usize,
}

impl TrainMetrics {
    pub fn accuracy(&self) -> f32 {
        if self.examples > 0.0 {
            self.correct / self.examples
        } else {
            0.0
        }
    }
}

/// The one model API every compute consumer runs through: single-PE
/// training (`Trainer`), the multi-PE plane (`ParallelTrainer`, via the
/// host backend's per-PE step engine), evaluation, and serving (via
/// [`Predictor`]). Implementations must be deterministic: identical
/// `(state, mfg, feats, labels, lr)` inputs produce bit-identical
/// parameter updates.
pub trait GnnModel: Send + Sync {
    fn dims(&self) -> ModelDims;

    /// Backend name for logs/manifests (`"host"` / `"pjrt"`).
    fn backend(&self) -> &'static str;

    /// One optimizer step on a (possibly merged) MFG. `feats` is the
    /// dense row-major feature buffer of the MFG's input vertices
    /// (`mfg.input_vertices()` order, `d_in` floats per row); `labels`
    /// is the full per-vertex label table indexed by global id. Loss is
    /// the masked mean cross-entropy over the seed rows; the update is
    /// bias-corrected Adam (`ParamState::adam_step` ==
    /// `python/compile/model.py::train_step`).
    fn train_on_mfg(
        &self,
        state: &mut ParamState,
        mfg: &Mfg,
        feats: &[f32],
        labels: &[u16],
        lr: f32,
    ) -> crate::Result<TrainMetrics>;

    /// Seed logits `[n0 × classes]` (row-major) for an evaluation MFG.
    fn forward_on_mfg(
        &self,
        state: &ParamState,
        mfg: &Mfg,
        feats: &[f32],
    ) -> crate::Result<Vec<f32>>;

    /// Snapshot the parameters into a forward-only [`Predictor`].
    fn predictor(&self, state: &ParamState) -> Predictor {
        Predictor::new(self.dims(), state.params.clone())
    }
}

/// A cheap, clonable, `Send` parameter snapshot for forward-only
/// consumers — what the serving executor ships to its prefetch thread
/// and what evaluation runs through: predictions run the full layered
/// model over each PE's [`PeCompute`] blocks instead of a single-row
/// head.
#[derive(Clone, Debug)]
pub struct Predictor {
    dims: ModelDims,
    params: Arc<Vec<Vec<f32>>>,
}

impl Predictor {
    pub fn new(dims: ModelDims, params: Vec<Vec<f32>>) -> Predictor {
        Predictor { dims, params: Arc::new(params) }
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn classes(&self) -> usize {
        self.dims.classes
    }

    /// Layered forward over every PE of one minibatch; returns per-PE
    /// predicted classes in seed order (`PeCompute::seeds`).
    /// Cooperative batches exchange hidden activations between the
    /// per-PE contexts exactly like the training plane (serially here —
    /// prediction is a read-only pass, determinism over parallelism).
    pub fn predict_minibatch(&self, pes: &[(&PeCompute, &[f32])]) -> Vec<Vec<u16>> {
        let logits = self.logits_minibatch(pes);
        logits
            .into_iter()
            .map(|per_pe| {
                per_pe
                    .chunks_exact(self.dims.classes.max(1))
                    .map(|row| kernels::argmax(row) as u16)
                    .collect()
            })
            .collect()
    }

    /// Per-PE seed logits (`[n_seeds × classes]` flat) of one
    /// minibatch; see [`Predictor::predict_minibatch`].
    pub fn logits_minibatch(&self, pes: &[(&PeCompute, &[f32])]) -> Vec<Vec<f32>> {
        host::forward_minibatch(self.dims, &self.params, pes)
    }

    /// Degenerate single-row forward treating `x` as a vertex with no
    /// sampled neighbors (every aggregation is the self row at weight
    /// 1); returns the class logits. A diagnostic/test convenience —
    /// real predictions go through [`Predictor::predict_minibatch`].
    pub fn logits_isolated(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dims.d_in, "logits_isolated feature width");
        let mut h = x.to_vec();
        for l in (0..self.dims.layers).rev() {
            let d = self.dims.depth_of(l);
            let (din, dout) = (self.dims.in_dim(l), self.dims.out_dim(l));
            let mut out = vec![0f32; dout];
            kernels::matmul_bias(&h, &self.params[2 * d], &self.params[2 * d + 1], 1, din, dout, &mut out);
            if l != 0 {
                kernels::relu(&mut out);
            }
            h = out;
        }
        h
    }

    /// Class prediction of [`Predictor::logits_isolated`].
    pub fn predict_isolated(&self, x: &[f32]) -> u16 {
        kernels::argmax(&self.logits_isolated(x)) as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_shapes_mirror_python_convention() {
        let dims = ModelDims { layers: 3, d_in: 16, hidden: 32, classes: 8 };
        let shapes = dims.param_shapes();
        assert_eq!(
            shapes,
            vec![
                vec![16, 32],
                vec![32],
                vec![32, 32],
                vec![32],
                vec![32, 8],
                vec![8]
            ]
        );
        assert_eq!(dims.num_scalars(), 16 * 32 + 32 + 32 * 32 + 32 + 32 * 8 + 8);
        // block↔param mapping: deepest block consumes features with the
        // input-first parameter pair
        assert_eq!(dims.depth_of(2), 0);
        assert_eq!(dims.in_dim(2), 16);
        assert_eq!(dims.out_dim(2), 32);
        assert_eq!(dims.in_dim(0), 32);
        assert_eq!(dims.out_dim(0), 8);
    }

    #[test]
    fn single_layer_dims_collapse() {
        let dims = ModelDims { layers: 1, d_in: 5, hidden: 99, classes: 3 };
        assert_eq!(dims.param_shapes(), vec![vec![5, 3], vec![3]]);
        assert_eq!(dims.in_dim(0), 5);
        assert_eq!(dims.out_dim(0), 3);
    }

    #[test]
    fn init_state_matches_with_shapes() {
        let dims = ModelDims { layers: 2, d_in: 6, hidden: 8, classes: 4 };
        let a = dims.init_state(7);
        let b = ParamState::with_shapes(dims.param_shapes(), 7);
        assert!(a.bits_eq(&b));
    }
}
