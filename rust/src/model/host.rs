//! The host (plain-Rust) backend of the compute plane: layered
//! gather→aggregate→matmul forward/backward over [`HostBlock`]s,
//! numerically mirroring `python/compile/model.py`.
//!
//! Two entry levels:
//!
//! * [`HostModel`] — the [`GnnModel`] backend for whole (possibly
//!   merged) MFGs: single-context forward, masked-mean cross-entropy,
//!   full backward, Adam. What `Trainer` runs when no PJRT artifacts
//!   are configured, and what the golden-vector parity test checks
//!   against the Python model.
//! * [`PeStep`] — the per-PE step engine of the multi-PE plane: the
//!   same kernels phase-split so `ParallelTrainer` can interleave the
//!   per-level compute with activation exchanges on the fabric
//!   (serially via `Exchange::route_rows`, threaded via
//!   `PeEndpoint::all_to_all_rows`). Each phase is pure per-PE f32
//!   work in deterministic order, so serial and threaded execution of
//!   the same minibatch are bit-identical.

// Allowlisted timing module (coopgnn-lint `wallclock` + clippy
// disallowed-methods): kernel-profiling reads feed compute_ms
// breakdowns only; no model math depends on them.
#![allow(clippy::disallowed_methods)]

use super::{blocks_from_mfg, kernels, GnnModel, ModelDims, PeCompute, TrainMetrics};
use crate::runtime::tensors::ParamState;
use crate::sampling::Mfg;
use std::time::Instant;

/// The default, artifact-free model backend.
#[derive(Clone, Copy, Debug)]
pub struct HostModel {
    dims: ModelDims,
}

impl HostModel {
    pub fn new(dims: ModelDims) -> HostModel {
        HostModel { dims }
    }
}

impl GnnModel for HostModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn backend(&self) -> &'static str {
        "host"
    }

    fn train_on_mfg(
        &self,
        state: &mut ParamState,
        mfg: &Mfg,
        feats: &[f32],
        labels: &[u16],
        lr: f32,
    ) -> crate::Result<TrainMetrics> {
        let dims = self.dims;
        anyhow::ensure!(mfg.num_layers() == dims.layers, "MFG depth {} vs model layers {}", mfg.num_layers(), dims.layers);
        anyhow::ensure!(
            feats.len() == mfg.input_vertices().len() * dims.d_in,
            "feature buffer {} floats, want {}×{}",
            feats.len(),
            mfg.input_vertices().len(),
            dims.d_in
        );
        let t0 = Instant::now();
        let comp = PeCompute {
            blocks: blocks_from_mfg(mfg),
            seeds: mfg.seeds().to_vec(),
            routes: None,
        };
        let pad_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let mut flat = vec![0f32; state.num_scalars()];
        let (loss_sum, correct, n) = {
            let mut step = PeStep::new(dims, &comp, feats, &state.params);
            step.forward_deepest();
            for l in (0..dims.layers - 1).rev() {
                step.forward_level(l, None);
            }
            let stats = step.loss_grad(labels);
            for l in 0..dims.layers {
                let out = step.backward_level(l, &mut flat);
                debug_assert!(out.is_none(), "independent step must not emit grad buckets");
            }
            stats
        };
        let denom = n.max(1.0);
        for g in flat.iter_mut() {
            *g /= denom;
        }
        state.adam_step(&flat, lr);
        Ok(TrainMetrics {
            loss: loss_sum / denom,
            correct,
            examples: n,
            pad_ms,
            exec_ms: t1.elapsed().as_secs_f64() * 1e3,
            truncated_vertices: 0,
            truncated_edges: 0,
        })
    }

    fn forward_on_mfg(
        &self,
        state: &ParamState,
        mfg: &Mfg,
        feats: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let dims = self.dims;
        anyhow::ensure!(mfg.num_layers() == dims.layers, "MFG depth {} vs model layers {}", mfg.num_layers(), dims.layers);
        anyhow::ensure!(
            feats.len() == mfg.input_vertices().len() * dims.d_in,
            "feature buffer {} floats, want {}×{}",
            feats.len(),
            mfg.input_vertices().len(),
            dims.d_in
        );
        let comp = PeCompute {
            blocks: blocks_from_mfg(mfg),
            seeds: mfg.seeds().to_vec(),
            routes: None,
        };
        let mut step = PeStep::new(dims, &comp, feats, &state.params);
        step.forward_deepest();
        for l in (0..dims.layers - 1).rev() {
            step.forward_level(l, None);
        }
        Ok(step.into_logits())
    }
}

/// Serial layered forward over the PEs of one minibatch — the
/// [`super::Predictor`] compute path (evaluation / serving).
/// Cooperative batches exchange activations between the contexts
/// directly (`buckets[src][dst] → inbox[dst][src]`, the fabric's
/// routing contract without the fabric).
pub fn forward_minibatch(
    dims: ModelDims,
    params: &[Vec<f32>],
    pes: &[(&PeCompute, &[f32])],
) -> Vec<Vec<f32>> {
    let coop = pes.iter().any(|(c, _)| c.routes.is_some());
    assert!(
        !coop || pes.iter().all(|(c, _)| c.routes.is_some()),
        "mixed cooperative/independent PEs in one minibatch"
    );
    let mut steps: Vec<PeStep> =
        pes.iter().map(|(c, f)| PeStep::new(dims, c, f, params)).collect();
    for s in steps.iter_mut() {
        s.forward_deepest();
    }
    for l in (0..dims.layers.saturating_sub(1)).rev() {
        if coop {
            let buckets: Vec<Vec<Vec<f32>>> = steps.iter().map(|s| s.send_rows(l)).collect();
            let p = steps.len();
            let mut inboxes: Vec<Vec<Vec<f32>>> = (0..p).map(|_| vec![Vec::new(); p]).collect();
            for (src, per_dst) in buckets.into_iter().enumerate() {
                for (dst, rows) in per_dst.into_iter().enumerate() {
                    inboxes[dst][src] = rows;
                }
            }
            for (s, inbox) in steps.iter_mut().zip(inboxes) {
                s.forward_level(l, Some(inbox));
            }
        } else {
            for s in steps.iter_mut() {
                s.forward_level(l, None);
            }
        }
    }
    steps.into_iter().map(|s| s.into_logits()).collect()
}

/// One PE's layered forward/backward context, phase-split around the
/// fabric rounds of the cooperative step:
///
/// forward: [`forward_deepest`] → per level `l = L-2..0`:
/// [`send_rows`] ⇄ fabric ⇄ [`forward_level`]; backward:
/// [`loss_grad`] → per level `l = 0..L-1`: [`backward_level`]
/// ⇄ fabric ⇄ [`absorb_grad_inbox`]. Independent mode skips every
/// fabric round (`forward_level(l, None)`; `backward_level` wires the
/// source gradient straight through).
///
/// Parameter gradients accumulate **unscaled** into a flat buffer laid
/// out in `ParamState` order; the caller appends `loss_sum/correct/n`,
/// all-reduces, scales by the global example count and applies
/// [`ParamState::adam_step`] — identical math to the single-context
/// [`HostModel::train_on_mfg`].
///
/// [`forward_deepest`]: PeStep::forward_deepest
/// [`send_rows`]: PeStep::send_rows
/// [`forward_level`]: PeStep::forward_level
/// [`loss_grad`]: PeStep::loss_grad
/// [`backward_level`]: PeStep::backward_level
/// [`absorb_grad_inbox`]: PeStep::absorb_grad_inbox
pub struct PeStep<'a> {
    dims: ModelDims,
    comp: &'a PeCompute,
    feats: &'a [f32],
    params: &'a [Vec<f32>],
    /// `agg[l]`: saved matmul input of block l (gather output).
    agg: Vec<Vec<f32>>,
    /// `h[l]`: saved block-l output rows (post-ReLU for l>0; logits at 0).
    h: Vec<Vec<f32>>,
    /// `d_h[l]`: gradient wrt `h[l]`, built up during backward.
    d_h: Vec<Vec<f32>>,
    /// flat-gradient offset of `(w_d, b_d)` per depth d.
    grad_off: Vec<(usize, usize)>,
    /// per-block gather/aggregate kernel ms (forward + backward).
    pub gather_ms: Vec<f64>,
    /// per-block matmul kernel ms (forward + backward).
    pub matmul_ms: Vec<f64>,
}

impl<'a> PeStep<'a> {
    pub fn new(dims: ModelDims, comp: &'a PeCompute, feats: &'a [f32], params: &'a [Vec<f32>]) -> PeStep<'a> {
        let ll = dims.layers;
        assert_eq!(comp.blocks.len(), ll, "PeCompute block count vs model layers");
        debug_assert_eq!(comp.seeds.len(), comp.blocks[0].n_dst, "seed count vs block 0 dst");
        debug_assert!(
            feats.len() >= comp.blocks[ll - 1].n_src * dims.d_in,
            "feature buffer covers block L-1 sources"
        );
        let shapes = dims.param_shapes();
        let mut grad_off = Vec::with_capacity(ll);
        let mut off = 0usize;
        for d in 0..ll {
            let wlen: usize = shapes[2 * d].iter().product();
            let blen: usize = shapes[2 * d + 1].iter().product();
            grad_off.push((off, off + wlen));
            off += wlen + blen;
        }
        PeStep {
            dims,
            comp,
            feats,
            params,
            agg: vec![Vec::new(); ll],
            h: vec![Vec::new(); ll],
            d_h: vec![Vec::new(); ll],
            grad_off,
            gather_ms: vec![0.0; ll],
            matmul_ms: vec![0.0; ll],
        }
    }

    pub fn examples(&self) -> usize {
        self.comp.seeds.len()
    }

    /// Seed logits (valid after the forward phases).
    pub fn logits(&self) -> &[f32] {
        &self.h[0]
    }

    pub fn into_logits(mut self) -> Vec<f32> {
        std::mem::take(&mut self.h[0])
    }

    /// gather→matmul(→ReLU) for block `l` from an explicit source buffer.
    fn run_block(&mut self, l: usize, src: &[f32]) {
        let b = &self.comp.blocks[l];
        let din = self.dims.in_dim(l);
        let dout = self.dims.out_dim(l);
        let t0 = Instant::now();
        let mut agg = vec![0f32; b.n_dst * din];
        kernels::gather_agg(b, src, din, &mut agg);
        self.gather_ms[l] += t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let d = self.dims.depth_of(l);
        let mut h = vec![0f32; b.n_dst * dout];
        kernels::matmul_bias(&agg, &self.params[2 * d], &self.params[2 * d + 1], b.n_dst, din, dout, &mut h);
        if l != 0 {
            kernels::relu(&mut h);
        }
        self.matmul_ms[l] += t1.elapsed().as_secs_f64() * 1e3;
        self.agg[l] = agg;
        self.h[l] = h;
    }

    /// Block `L-1` from the PE's loaded feature buffer (its source row
    /// space by construction).
    pub fn forward_deepest(&mut self) {
        let l = self.dims.layers - 1;
        let feats = self.feats;
        // borrow dance: run_block needs &mut self, feats is a plain ref
        let src: &[f32] = feats;
        self.run_block(l, src);
    }

    /// Activation rows other PEs requested from this PE at level `l`:
    /// `buckets[q]` = rows of `h[l+1]` at `routes.send_pos[l][q]`, flat
    /// `hidden` floats per row — feed to the fabric's row round.
    pub fn send_rows(&self, l: usize) -> Vec<Vec<f32>> {
        let dim = self.dims.out_dim(l + 1);
        let routes = self.comp.routes.as_ref().expect("send_rows without cooperative routes");
        let h = &self.h[l + 1];
        routes.send_pos[l]
            .iter()
            .map(|pos| {
                let mut buf = Vec::with_capacity(pos.len() * dim);
                for &p in pos {
                    let s = p as usize * dim;
                    buf.extend_from_slice(&h[s..s + dim]);
                }
                buf
            })
            .collect()
    }

    /// Compute block `l < L-1`. Cooperative: `inbox[src]` holds the
    /// hidden rows owner `src` shipped back (the fabric round fed by
    /// every PE's [`PeStep::send_rows`]); the dense source buffer is
    /// reassembled in Ṡ^l order by per-owner interleave. Independent
    /// (`None`): the source rows are exactly `h[l+1]` (prefix-nested
    /// local positions).
    pub fn forward_level(&mut self, l: usize, inbox: Option<Vec<Vec<f32>>>) {
        debug_assert!(l + 1 < self.dims.layers, "forward_level on the deepest block");
        match inbox {
            Some(inbox) => {
                let src = self.assemble_src(l, &inbox);
                self.run_block(l, &src);
            }
            None => {
                debug_assert_eq!(
                    self.comp.blocks[l].n_src,
                    self.comp.blocks[l + 1].n_dst,
                    "independent block chaining"
                );
                let src = std::mem::take(&mut self.h[l + 1]);
                self.run_block(l, &src);
                self.h[l + 1] = src;
            }
        }
    }

    fn assemble_src(&self, l: usize, inbox: &[Vec<f32>]) -> Vec<f32> {
        let dim = self.dims.hidden;
        let routes = self.comp.routes.as_ref().expect("cooperative level without routes");
        let order = &routes.recv_src[l];
        debug_assert_eq!(order.len(), self.comp.blocks[l].n_src, "route order vs block sources");
        let mut out = vec![0f32; order.len() * dim];
        let mut cursor = vec![0usize; inbox.len()];
        for (i, &o) in order.iter().enumerate() {
            let o = o as usize;
            let s = cursor[o] * dim;
            out[i * dim..(i + 1) * dim].copy_from_slice(&inbox[o][s..s + dim]);
            cursor[o] += 1;
        }
        out
    }

    /// Loss head: cross-entropy gradient into `d_h[0]`, returning
    /// `(loss_sum, correct, examples)` — unnormalized, summed globally
    /// by the caller's all-reduce. `labels` is the full per-vertex
    /// table (indexed by global id).
    pub fn loss_grad(&mut self, labels: &[u16]) -> (f32, f32, f32) {
        let classes = self.dims.classes;
        let lab: Vec<u16> = self.comp.seeds.iter().map(|&v| labels[v as usize]).collect();
        let n = lab.len();
        let mut d = vec![0f32; n * classes];
        let (loss_sum, correct) = kernels::softmax_xent(&self.h[0], &lab, classes, &mut d);
        self.d_h[0] = d;
        (loss_sum, correct, n as f32)
    }

    /// Backward through block `l` (ascending from the output):
    /// ReLU-mask `d_h[l]` (l>0), accumulate `w`/`b` gradients into the
    /// flat `grads` buffer, and propagate to the source rows. Returns
    /// the per-owner gradient buckets to route back in cooperative mode
    /// (`Some` for `l < L-1`); independent mode wires the source
    /// gradient straight into `d_h[l+1]` and returns `None`. Block
    /// `L-1` discards the (feature) source gradient entirely.
    pub fn backward_level(&mut self, l: usize, grads: &mut [f32]) -> Option<Vec<Vec<f32>>> {
        let dims = self.dims;
        let din = dims.in_dim(l);
        let dout = dims.out_dim(l);
        let d = dims.depth_of(l);
        let n_dst = self.comp.blocks[l].n_dst;
        if l > 0 {
            kernels::relu_backward(&self.h[l], &mut self.d_h[l]);
        }
        let (wo, bo) = self.grad_off[d];
        let t0 = Instant::now();
        let (wg, rest) = grads[wo..].split_at_mut(din * dout);
        kernels::matmul_backward_params(&self.agg[l], &self.d_h[l], n_dst, din, dout, wg, &mut rest[..dout]);
        debug_assert_eq!(wo + din * dout, bo, "bias follows its weight in the flat layout");
        let mut d_agg = vec![0f32; n_dst * din];
        kernels::matmul_backward_input(&self.d_h[l], &self.params[2 * d], n_dst, din, dout, &mut d_agg);
        self.matmul_ms[l] += t0.elapsed().as_secs_f64() * 1e3;
        if l == dims.layers - 1 {
            return None; // input-feature gradients are not needed
        }
        let b = &self.comp.blocks[l];
        let t1 = Instant::now();
        let mut d_src = vec![0f32; b.n_src * din];
        kernels::gather_agg_backward(b, &d_agg, din, &mut d_src);
        self.gather_ms[l] += t1.elapsed().as_secs_f64() * 1e3;
        match &self.comp.routes {
            None => {
                self.d_h[l + 1] = d_src;
                None
            }
            Some(routes) => {
                let order = &routes.recv_src[l];
                let npes = routes.send_pos[l].len();
                let mut buckets: Vec<Vec<f32>> = vec![Vec::new(); npes];
                for (i, &o) in order.iter().enumerate() {
                    buckets[o as usize].extend_from_slice(&d_src[i * din..(i + 1) * din]);
                }
                self.d_h[l + 1] = vec![0f32; self.comp.blocks[l + 1].n_dst * din];
                Some(buckets)
            }
        }
    }

    /// Owner side of the backward row round at level `l`: scatter-add
    /// each requester's gradient rows onto this PE's `d_h[l+1]` at the
    /// positions it served them from — the exact adjoint of
    /// [`PeStep::send_rows`]. Requesters are absorbed in ascending PE
    /// order, so serial and threaded accumulation orders agree.
    pub fn absorb_grad_inbox(&mut self, l: usize, inbox: Vec<Vec<f32>>) {
        let dim = self.dims.out_dim(l + 1);
        let routes = self.comp.routes.as_ref().expect("grad inbox without cooperative routes");
        let dh = &mut self.d_h[l + 1];
        for (q, rows) in inbox.iter().enumerate() {
            let pos = &routes.send_pos[l][q];
            debug_assert_eq!(rows.len(), pos.len() * dim, "requester {q} grad bucket size");
            for (ri, &p) in pos.iter().enumerate() {
                let dst = p as usize * dim;
                for (dv, &gv) in dh[dst..dst + dim].iter_mut().zip(&rows[ri * dim..(ri + 1) * dim]) {
                    *dv += gv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::sampling::block::build_mfg;
    use crate::sampling::{SamplerConfig, SamplerKind};

    fn fixture(layers: usize, seed: u64) -> (ModelDims, Mfg, Vec<f32>, Vec<u16>) {
        let g = generate::chung_lu(400, 8.0, 2.4, seed);
        let cfg = SamplerConfig { layers, fanout: 4, ..Default::default() };
        let mut s = cfg.build(SamplerKind::Neighbor, &g, seed);
        let seeds: Vec<u32> = (0..24).collect();
        let mfg = build_mfg(&mut s, &seeds);
        let dims = ModelDims { layers, d_in: 6, hidden: 8, classes: 5 };
        let n_in = mfg.input_vertices().len();
        let mut rng = crate::util::rng::Pcg64::new(seed ^ 0xF00D);
        let feats: Vec<f32> = (0..n_in * dims.d_in).map(|_| rng.next_f64() as f32 - 0.5).collect();
        let labels: Vec<u16> = (0..g.num_vertices()).map(|v| (v % dims.classes as u32) as u16).collect();
        (dims, mfg, feats, labels)
    }

    /// Forward + summed loss through the model, for finite differences.
    fn loss_of(dims: ModelDims, state: &ParamState, mfg: &Mfg, feats: &[f32], labels: &[u16]) -> f64 {
        let model = HostModel::new(dims);
        let logits = model.forward_on_mfg(state, mfg, feats).unwrap();
        let lab: Vec<u16> = mfg.seeds().iter().map(|&v| labels[v as usize]).collect();
        let mut d = vec![0f32; logits.len()];
        let (loss_sum, _) = kernels::softmax_xent(&logits, &lab, dims.classes, &mut d);
        loss_sum as f64 / lab.len() as f64
    }

    #[test]
    fn layered_gradients_match_finite_differences() {
        let (dims, mfg, feats, labels) = fixture(2, 11);
        let state = dims.init_state(3);
        // analytic flat gradient via the PeStep path (scaled by 1/n like
        // the train step)
        let comp = PeCompute { blocks: blocks_from_mfg(&mfg), seeds: mfg.seeds().to_vec(), routes: None };
        let mut flat = vec![0f32; state.num_scalars()];
        let n = {
            let mut step = PeStep::new(dims, &comp, &feats, &state.params);
            step.forward_deepest();
            for l in (0..dims.layers - 1).rev() {
                step.forward_level(l, None);
            }
            let (_, _, n) = step.loss_grad(&labels);
            for l in 0..dims.layers {
                step.backward_level(l, &mut flat);
            }
            n
        };
        for g in flat.iter_mut() {
            *g /= n;
        }
        // probe a spread of parameters in every tensor
        let mut off = 0usize;
        for (pi, shape) in dims.param_shapes().iter().enumerate() {
            let len: usize = shape.iter().product();
            for &j in &[0usize, len / 2, len - 1] {
                let mut hi = ParamState::with_shapes(dims.param_shapes(), 3);
                hi.params[pi][j] += 1e-2;
                let mut lo = ParamState::with_shapes(dims.param_shapes(), 3);
                lo.params[pi][j] -= 1e-2;
                let fd = ((loss_of(dims, &hi, &mfg, &feats, &labels)
                    - loss_of(dims, &lo, &mfg, &feats, &labels))
                    / 2e-2) as f32;
                let an = flat[off + j];
                assert!(
                    (fd - an).abs() < 3e-3,
                    "param {pi}[{j}]: fd {fd} vs analytic {an}"
                );
            }
            off += len;
        }
    }

    #[test]
    fn train_on_mfg_reduces_loss_and_is_deterministic() {
        let (dims, mfg, feats, labels) = fixture(3, 7);
        let model = HostModel::new(dims);
        let mut s1 = dims.init_state(9);
        let mut s2 = dims.init_state(9);
        let mut first = 0f32;
        let mut last = 0f32;
        for i in 0..25 {
            let m1 = model.train_on_mfg(&mut s1, &mfg, &feats, &labels, 0.05).unwrap();
            let m2 = model.train_on_mfg(&mut s2, &mfg, &feats, &labels, 0.05).unwrap();
            assert_eq!(m1.loss.to_bits(), m2.loss.to_bits(), "step {i} determinism");
            if i == 0 {
                first = m1.loss;
            }
            last = m1.loss;
        }
        assert!(s1.bits_eq(&s2), "identical steps keep states bit-identical");
        assert!(last < first * 0.9, "loss must drop on a fixed batch: {first} → {last}");
        assert_eq!(s1.step, 25.0);
    }

    #[test]
    fn forward_on_mfg_matches_predictor_minibatch() {
        let (dims, mfg, feats, labels) = fixture(2, 5);
        let _ = labels;
        let model = HostModel::new(dims);
        let state = dims.init_state(4);
        let logits = model.forward_on_mfg(&state, &mfg, &feats).unwrap();
        let comp = PeCompute { blocks: blocks_from_mfg(&mfg), seeds: mfg.seeds().to_vec(), routes: None };
        let via_pred = model.predictor(&state).logits_minibatch(&[(&comp, feats.as_slice())]);
        assert_eq!(via_pred.len(), 1);
        assert_eq!(logits, via_pred[0], "one API, one forward");
    }

    #[test]
    fn dims_mismatch_is_an_error() {
        let (dims, mfg, feats, labels) = fixture(2, 6);
        let wrong = ModelDims { layers: 3, ..dims };
        let model = HostModel::new(wrong);
        let mut state = wrong.init_state(1);
        assert!(model.train_on_mfg(&mut state, &mfg, &feats, &labels, 0.1).is_err());
        assert!(model.forward_on_mfg(&state, &mfg, &feats).is_err());
    }
}
