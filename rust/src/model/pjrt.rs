//! The PJRT/AOT bridge backend of the compute plane: the same
//! [`GnnModel`] contract as [`super::HostModel`], routed through
//! compiled train/forward executables over fixed-shape padded batches
//! (`python/compile/model.py` flat calling convention).
//!
//! This backend owns everything fixed-shape: MFG → [`PaddedBatch`]
//! padding against the artifact's [`ShapeCaps`], the padded `[cap × d]`
//! feature tensor, literal assembly (`train_inputs` / `forward_inputs`)
//! and output absorption — the marshalling that used to be inlined in
//! `Trainer`. In this build [`crate::runtime::Runtime::cpu`] is a stub,
//! so a `PjrtModel` can only be constructed where real artifacts and a
//! PJRT-enabled build exist; the host backend is the default
//! everywhere else. Nothing above the trait knows the difference.

use super::{GnnModel, ModelDims, TrainMetrics};
use crate::runtime::manifest::ArtifactConfig;
use crate::runtime::tensors::{forward_inputs, to_vec_f32, train_inputs, ParamState};
use crate::runtime::{Executable, Runtime};
use crate::sampling::Mfg;
use crate::util::stats::Timer;

/// Compiled-executable model backend (drop-in behind [`GnnModel`]).
pub struct PjrtModel {
    dims: ModelDims,
    art: ArtifactConfig,
    train_exe: Executable,
    forward_exe: Executable,
}

impl PjrtModel {
    /// Compile the artifact's train/forward HLO on `rt` and bind the
    /// model dims from the manifest entry.
    pub fn load(rt: &Runtime, art: ArtifactConfig) -> crate::Result<PjrtModel> {
        let train_exe = rt.load_hlo_text(&art.train_hlo)?;
        let forward_exe = rt.load_hlo_text(&art.forward_hlo)?;
        let dims = ModelDims {
            layers: art.layers,
            d_in: art.d_in,
            hidden: art.hidden,
            classes: art.classes,
        };
        Ok(PjrtModel { dims, art, train_exe, forward_exe })
    }

    pub fn art(&self) -> &ArtifactConfig {
        &self.art
    }

    /// Pad the dense `S^L × d` buffer into the fixed `[cap × d]` input
    /// tensor (prefix copy — the clipped input list is a prefix of S^L).
    fn pad_feats(&self, mfg: &Mfg, feats: &[f32]) -> crate::Result<Vec<f32>> {
        let cap = *self.art.caps.n.last().unwrap();
        let d = self.dims.d_in;
        anyhow::ensure!(
            feats.len() == mfg.input_vertices().len() * d,
            "feature buffer {} floats, want {}×{}",
            feats.len(),
            mfg.input_vertices().len(),
            d
        );
        let mut buf = vec![0f32; cap * d];
        let keep = mfg.clipped_input_vertices(&self.art.caps).len() * d;
        buf[..keep].copy_from_slice(&feats[..keep]);
        Ok(buf)
    }
}

impl GnnModel for PjrtModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn train_on_mfg(
        &self,
        state: &mut ParamState,
        mfg: &Mfg,
        feats: &[f32],
        labels: &[u16],
        lr: f32,
    ) -> crate::Result<TrainMetrics> {
        anyhow::ensure!(mfg.num_layers() == self.dims.layers, "MFG depth {} vs model layers {}", mfg.num_layers(), self.dims.layers);
        let t = Timer::start();
        let batch = mfg.pad(&self.art.caps, |v| labels[v as usize]);
        let feat_buf = self.pad_feats(mfg, feats)?;
        let pad_ms = t.elapsed_ms();

        let t = Timer::start();
        let inputs = train_inputs(&self.art, state, &feat_buf, &batch, lr)?;
        let outs = self.train_exe.run(&inputs)?;
        let (loss, correct) = state.absorb(&outs)?;
        let exec_ms = t.elapsed_ms();
        let examples = batch.label_mask.iter().sum::<f32>();
        Ok(TrainMetrics {
            loss,
            correct,
            examples,
            pad_ms,
            exec_ms,
            truncated_vertices: batch.truncated_vertices,
            truncated_edges: batch.truncated_edges,
        })
    }

    fn forward_on_mfg(
        &self,
        state: &ParamState,
        mfg: &Mfg,
        feats: &[f32],
    ) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(mfg.num_layers() == self.dims.layers, "MFG depth {} vs model layers {}", mfg.num_layers(), self.dims.layers);
        let batch = {
            // forward batches carry no labels; the padded block tensors
            // are all that matters
            mfg.pad(&self.art.caps, |_| 0)
        };
        let feat_buf = self.pad_feats(mfg, feats)?;
        let inputs = forward_inputs(&self.art, state, &feat_buf, &batch)?;
        let outs = self.forward_exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 1, "forward returns 1 output");
        let full = to_vec_f32(&outs[0])?;
        // clip the padded [cap_0 × C] logits down to the real seed rows
        let n0 = mfg.seeds().len().min(self.art.caps.n[0]);
        Ok(full[..n0 * self.dims.classes].to_vec())
    }
}
