//! Host f32 kernels of the layered compute plane — forward and backward
//! twins of `python/compile/kernels/{gather_agg.py,matmul.py}` plus the
//! masked softmax cross-entropy of `model.py::loss_and_metrics`.
//!
//! All kernels are plain loops with deterministic accumulation order:
//! neighbor edges in CSR order then the self edge (the summation order
//! of the padded `gather_agg`), matmul reductions over the input
//! dimension in ascending index order. Replicated calls on identical
//! inputs are bit-identical — the property every lockstep oracle in the
//! training plane builds on.

use super::HostBlock;

/// Weighted mean aggregation `out[i] = Σ_e w_e·src[nbr_e] + w_self·src[self_i]`
/// over a [`HostBlock`] — forward of `gather_agg`. `out` must hold
/// `n_dst * dim` floats and is overwritten.
pub fn gather_agg(b: &HostBlock, src: &[f32], dim: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), b.n_dst * dim, "gather_agg out size");
    debug_assert!(src.len() >= b.n_src * dim, "gather_agg src size");
    for i in 0..b.n_dst {
        let row = &mut out[i * dim..(i + 1) * dim];
        row.fill(0.0);
        for e in b.offsets[i] as usize..b.offsets[i + 1] as usize {
            let s = b.nbr_pos[e] as usize * dim;
            let w = b.nbr_w[e];
            for (r, &x) in row.iter_mut().zip(&src[s..s + dim]) {
                *r += w * x;
            }
        }
        let s = b.self_pos[i] as usize * dim;
        let w = b.self_w[i];
        for (r, &x) in row.iter_mut().zip(&src[s..s + dim]) {
            *r += w * x;
        }
    }
}

/// Backward of [`gather_agg`]: scatter-add `d_out` rows back onto the
/// source rows through the same weights. `d_src` must hold
/// `n_src * dim` floats; contributions **accumulate** (callers zero it).
pub fn gather_agg_backward(b: &HostBlock, d_out: &[f32], dim: usize, d_src: &mut [f32]) {
    debug_assert_eq!(d_out.len(), b.n_dst * dim, "gather_agg_backward d_out size");
    debug_assert_eq!(d_src.len(), b.n_src * dim, "gather_agg_backward d_src size");
    for i in 0..b.n_dst {
        let g = &d_out[i * dim..(i + 1) * dim];
        for e in b.offsets[i] as usize..b.offsets[i + 1] as usize {
            let s = b.nbr_pos[e] as usize * dim;
            let w = b.nbr_w[e];
            for (d, &x) in d_src[s..s + dim].iter_mut().zip(g) {
                *d += w * x;
            }
        }
        let s = b.self_pos[i] as usize * dim;
        let w = b.self_w[i];
        for (d, &x) in d_src[s..s + dim].iter_mut().zip(g) {
            *d += w * x;
        }
    }
}

/// Row-major dense `out = x·w + b` (`x: [n × d_in]`, `w: [d_in × d_out]`,
/// `b: [d_out]`) — forward of `matmul` plus the bias add of the model's
/// layer recursion. `out` is overwritten.
pub fn matmul_bias(x: &[f32], w: &[f32], bias: &[f32], n: usize, d_in: usize, d_out: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), n * d_in, "matmul_bias x size");
    debug_assert_eq!(w.len(), d_in * d_out, "matmul_bias w size");
    debug_assert_eq!(bias.len(), d_out, "matmul_bias bias size");
    debug_assert_eq!(out.len(), n * d_out, "matmul_bias out size");
    for i in 0..n {
        let row = &mut out[i * d_out..(i + 1) * d_out];
        row.copy_from_slice(bias);
        let xr = &x[i * d_in..(i + 1) * d_in];
        for (j, &xj) in xr.iter().enumerate() {
            let wr = &w[j * d_out..(j + 1) * d_out];
            for (r, &wv) in row.iter_mut().zip(wr) {
                *r += xj * wv;
            }
        }
    }
}

/// Parameter gradients of [`matmul_bias`]: `dw += xᵀ·d_y`, `db += Σ_i d_y[i]`.
/// Accumulates (callers zero `dw`/`db` once per step).
pub fn matmul_backward_params(
    x: &[f32],
    d_y: &[f32],
    n: usize,
    d_in: usize,
    d_out: usize,
    dw: &mut [f32],
    db: &mut [f32],
) {
    debug_assert_eq!(dw.len(), d_in * d_out, "matmul_backward_params dw size");
    debug_assert_eq!(db.len(), d_out, "matmul_backward_params db size");
    for i in 0..n {
        let g = &d_y[i * d_out..(i + 1) * d_out];
        let xr = &x[i * d_in..(i + 1) * d_in];
        for (j, &xj) in xr.iter().enumerate() {
            let dwr = &mut dw[j * d_out..(j + 1) * d_out];
            for (d, &gv) in dwr.iter_mut().zip(g) {
                *d += xj * gv;
            }
        }
        for (d, &gv) in db.iter_mut().zip(g) {
            *d += gv;
        }
    }
}

/// Input gradient of [`matmul_bias`]: `d_x = d_y·wᵀ`. Overwrites `d_x`.
pub fn matmul_backward_input(d_y: &[f32], w: &[f32], n: usize, d_in: usize, d_out: usize, d_x: &mut [f32]) {
    debug_assert_eq!(d_x.len(), n * d_in, "matmul_backward_input d_x size");
    for i in 0..n {
        let g = &d_y[i * d_out..(i + 1) * d_out];
        let dxr = &mut d_x[i * d_in..(i + 1) * d_in];
        for (j, dx) in dxr.iter_mut().enumerate() {
            let wr = &w[j * d_out..(j + 1) * d_out];
            let mut acc = 0f32;
            for (&gv, &wv) in g.iter().zip(wr) {
                acc += gv * wv;
            }
            *dx = acc;
        }
    }
}

/// In-place `max(x, 0)` — the inter-layer nonlinearity.
pub fn relu(buf: &mut [f32]) {
    for v in buf.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Backward of [`relu`] given the **saved post-activation** output:
/// zeroes `d` wherever the forward output was clamped.
pub fn relu_backward(saved_out: &[f32], d: &mut [f32]) {
    debug_assert_eq!(saved_out.len(), d.len(), "relu_backward size");
    for (g, &y) in d.iter_mut().zip(saved_out) {
        if y <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Stable softmax cross-entropy over `[n × classes]` logits: returns
/// `(loss_sum, correct)` (unnormalized — the caller divides by the
/// global example count after the all-reduce, mirroring the masked mean
/// of `loss_and_metrics`) and writes the **unscaled** gradient
/// `softmax - onehot` into `d_logits`.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[u16],
    classes: usize,
    d_logits: &mut [f32],
) -> (f32, f32) {
    let n = labels.len();
    debug_assert_eq!(logits.len(), n * classes, "softmax_xent logits size");
    debug_assert_eq!(d_logits.len(), n * classes, "softmax_xent d_logits size");
    let mut loss_sum = 0f32;
    let mut correct = 0f32;
    for i in 0..n {
        let row = &logits[i * classes..(i + 1) * classes];
        let y = labels[i] as usize;
        debug_assert!(y < classes, "label out of range");
        let mut mx = row[0];
        for &v in &row[1..] {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0f32;
        let g = &mut d_logits[i * classes..(i + 1) * classes];
        for (gv, &v) in g.iter_mut().zip(row) {
            let e = (v - mx).exp();
            *gv = e;
            sum += e;
        }
        loss_sum += sum.ln() - (row[y] - mx);
        let inv = 1.0 / sum;
        for gv in g.iter_mut() {
            *gv *= inv;
        }
        g[y] -= 1.0;
        if argmax(row) == y {
            correct += 1.0;
        }
    }
    (loss_sum, correct)
}

/// First-maximum argmax with the NaN tie-break every consumer shares
/// (a NaN entry never wins unless it is at index 0 and everything else
/// is NaN too) — one copy for every prediction consumer.
pub fn argmax(row: &[f32]) -> usize {
    debug_assert!(!row.is_empty(), "argmax of empty row");
    let mut best = row[0];
    let mut bi = 0usize;
    for (c, &v) in row.iter().enumerate().skip(1) {
        if v > best {
            best = v;
            bi = c;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 dst, 3 src: dst0 ← {src1, src2} + self src0; dst1 ← {} + self src2.
    fn block() -> HostBlock {
        HostBlock {
            n_dst: 2,
            n_src: 3,
            offsets: vec![0, 2, 2],
            nbr_pos: vec![1, 2],
            nbr_w: vec![1.0 / 3.0, 1.0 / 3.0],
            self_pos: vec![0, 2],
            self_w: vec![1.0 / 3.0, 1.0],
        }
    }

    #[test]
    fn gather_agg_weighted_mean() {
        let b = block();
        let src = vec![3.0, 0.0, 6.0, 0.0, 9.0, 3.0]; // dim 2
        let mut out = vec![0f32; 4];
        gather_agg(&b, &src, 2, &mut out);
        assert_eq!(out, vec![6.0, 1.0, 9.0, 3.0]);
    }

    #[test]
    fn gather_backward_transposes_forward() {
        // ⟨gather(x), g⟩ == ⟨x, gather_backward(g)⟩ — adjoint identity
        let b = block();
        let dim = 2;
        let src: Vec<f32> = (0..b.n_src * dim).map(|i| (i as f32) * 0.3 - 1.0).collect();
        let g: Vec<f32> = (0..b.n_dst * dim).map(|i| 1.0 - (i as f32) * 0.7).collect();
        let mut fwd = vec![0f32; b.n_dst * dim];
        gather_agg(&b, &src, dim, &mut fwd);
        let mut bwd = vec![0f32; b.n_src * dim];
        gather_agg_backward(&b, &g, dim, &mut bwd);
        let lhs: f32 = fwd.iter().zip(&g).map(|(a, b)| a * b).sum();
        let rhs: f32 = src.iter().zip(&bwd).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn matmul_bias_and_gradients_agree_with_finite_differences() {
        let (n, din, dout) = (3usize, 4usize, 2usize);
        let x: Vec<f32> = (0..n * din).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let w: Vec<f32> = (0..din * dout).map(|i| 0.2 - (i as f32) * 0.05).collect();
        let b: Vec<f32> = vec![0.1, -0.2];
        let mut y = vec![0f32; n * dout];
        matmul_bias(&x, &w, &b, n, din, dout, &mut y);
        // scalar objective L = Σ y² / 2 ⇒ dL/dy = y
        let mut dw = vec![0f32; din * dout];
        let mut db = vec![0f32; dout];
        matmul_backward_params(&x, &y, n, din, dout, &mut dw, &mut db);
        let mut dx = vec![0f32; n * din];
        matmul_backward_input(&y, &w, n, din, dout, &mut dx);
        let loss = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
            let mut y = vec![0f32; n * dout];
            matmul_bias(x, w, b, n, din, dout, &mut y);
            y.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
        };
        let eps = 1e-3f32;
        for (buf, grad, kind) in [
            (x.clone(), dx.clone(), "x"),
            (w.clone(), dw.clone(), "w"),
            (b.clone(), db.clone(), "b"),
        ] {
            for i in 0..buf.len() {
                let mut hi = buf.clone();
                hi[i] += eps;
                let mut lo = buf.clone();
                lo[i] -= eps;
                let (fhi, flo) = match kind {
                    "x" => (loss(&hi, &w, &b), loss(&lo, &w, &b)),
                    "w" => (loss(&x, &hi, &b), loss(&x, &lo, &b)),
                    _ => (loss(&x, &w, &hi), loss(&x, &w, &lo)),
                };
                let fd = ((fhi - flo) / (2.0 * eps as f64)) as f32;
                assert!(
                    (fd - grad[i]).abs() < 2e-2,
                    "{kind}[{i}]: fd {fd} vs analytic {}",
                    grad[i]
                );
            }
        }
    }

    #[test]
    fn softmax_xent_matches_hand_computation() {
        // single row [0, ln2], label 1: softmax = [1/3, 2/3]
        let logits = vec![0.0f32, std::f32::consts::LN_2];
        let mut d = vec![0f32; 2];
        let (loss, correct) = softmax_xent(&logits, &[1], 2, &mut d);
        assert!((loss - (1.5f32).ln()).abs() < 1e-6, "loss {loss}");
        assert_eq!(correct, 1.0);
        assert!((d[0] - 1.0 / 3.0).abs() < 1e-6);
        assert!((d[1] + 1.0 / 3.0).abs() < 1e-6);
        // gradient of each row sums to zero
        assert!((d[0] + d[1]).abs() < 1e-6);
    }

    #[test]
    fn argmax_first_max_wins_and_skips_nan() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[0.5, f32::NAN, 0.4]), 0);
    }

    #[test]
    fn relu_roundtrip_masks_gradient() {
        let mut h = vec![-1.0f32, 0.0, 2.0];
        relu(&mut h);
        assert_eq!(h, vec![0.0, 0.0, 2.0]);
        let mut d = vec![5.0f32, 5.0, 5.0];
        relu_backward(&h, &mut d);
        assert_eq!(d, vec![0.0, 0.0, 5.0]);
    }
}
