//! Binary graph / partition IO.
//!
//! Datasets regenerate deterministically from the registry, so this format
//! is a *cache* to avoid re-running generation inside the repro harnesses
//! (papers-s takes a couple seconds to synthesize). Format: magic,
//! version, u64 sizes, raw little-endian arrays.

use super::csr::Csr;
use super::partition::Partition;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"COOPGNN1";

/// Serialize a CSR graph to `path`.
pub fn save_graph(g: &Csr, path: &Path) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(g.indptr.len() as u64).to_le_bytes())?;
    f.write_all(&(g.indices.len() as u64).to_le_bytes())?;
    for v in &g.indptr {
        f.write_all(&v.to_le_bytes())?;
    }
    for v in &g.indices {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a CSR graph from `path`.
pub fn load_graph(path: &Path) -> crate::Result<Csr> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}");
    let np = read_u64(&mut f)? as usize;
    let ne = read_u64(&mut f)? as usize;
    let mut indptr = vec![0u64; np];
    for v in indptr.iter_mut() {
        *v = read_u64(&mut f)?;
    }
    let mut indices = vec![0u32; ne];
    let mut buf = [0u8; 4];
    for v in indices.iter_mut() {
        f.read_exact(&mut buf)?;
        *v = u32::from_le_bytes(buf);
    }
    Ok(Csr { indptr, indices })
}

/// Serialize a partition.
pub fn save_partition(p: &Partition, path: &Path) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(p.num_parts as u64).to_le_bytes())?;
    f.write_all(&(p.assignment.len() as u64).to_le_bytes())?;
    for a in &p.assignment {
        f.write_all(&a.to_le_bytes())?;
    }
    Ok(())
}

/// Load a partition.
pub fn load_partition(path: &Path) -> crate::Result<Partition> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}");
    let num_parts = read_u64(&mut f)? as usize;
    let n = read_u64(&mut f)? as usize;
    let mut assignment = vec![0u16; n];
    let mut buf = [0u8; 2];
    for a in assignment.iter_mut() {
        f.read_exact(&mut buf)?;
        *a = u16::from_le_bytes(buf);
    }
    Ok(Partition { assignment, num_parts })
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, partition};

    #[test]
    fn graph_roundtrip() {
        let g = generate::erdos_renyi(300, 1500, 8);
        let dir = std::env::temp_dir().join("coopgnn_io_test");
        let path = dir.join("g.bin");
        save_graph(&g, &path).unwrap();
        let g2 = load_graph(&path).unwrap();
        assert_eq!(g.indptr, g2.indptr);
        assert_eq!(g.indices, g2.indices);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partition_roundtrip() {
        let g = generate::erdos_renyi(200, 800, 9);
        let p = partition::random(&g, 4, 1);
        let dir = std::env::temp_dir().join("coopgnn_io_test2");
        let path = dir.join("p.bin");
        save_partition(&p, &path).unwrap();
        let p2 = load_partition(&path).unwrap();
        assert_eq!(p.assignment, p2.assignment);
        assert_eq!(p.num_parts, p2.num_parts);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("coopgnn_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"NOTMAGIC        ").unwrap();
        assert!(load_graph(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
