//! Compressed Sparse Row graph storage.
//!
//! Edges are stored by *destination*: `neighbors(s)` returns the in-
//! neighborhood `N(s) = { t | (t -> s) in E }`, matching the paper's
//! message-flow convention (embeddings flow from `t` to `s`, Eq. 1).

use crate::util::rng::Pcg64;

/// Vertex identifier. u32 bounds us at ~4B vertices; the synthetic
/// datasets here stay well below that while halving index memory.
pub type VertexId = u32;

/// Immutable CSR graph.
#[derive(Clone, Debug, Default)]
pub struct Csr {
    /// `indptr[v]..indptr[v+1]` spans `indices` entries holding N(v).
    pub indptr: Vec<u64>,
    /// Concatenated in-neighbor lists.
    pub indices: Vec<VertexId>,
}

impl Csr {
    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.indptr.len().saturating_sub(1)
    }

    /// Number of (directed) edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    /// In-degree of `v`.
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.indptr[v as usize + 1] - self.indptr[v as usize]) as usize
    }

    /// In-neighborhood slice of `v`.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.indptr[v as usize] as usize;
        let hi = self.indptr[v as usize + 1] as usize;
        &self.indices[lo..hi]
    }

    /// Average degree |E| / |V|.
    pub fn avg_degree(&self) -> f64 {
        if self.num_vertices() == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_vertices() as f64
        }
    }

    /// Maximum in-degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Build the reverse graph (out-neighborhoods become in-neighborhoods).
    pub fn reverse(&self) -> Csr {
        let n = self.num_vertices();
        let mut b = CsrBuilder::new(n);
        for s in 0..n as VertexId {
            for &t in self.neighbors(s) {
                b.add_edge(s, t); // reversed: s -> t in the new graph
            }
        }
        b.finish()
    }

    /// Make the graph undirected by unioning each edge with its reverse
    /// (dedup applied). The paper does this for papers100M/mag240M and for
    /// the edge-prediction experiments.
    pub fn to_undirected(&self) -> Csr {
        let n = self.num_vertices();
        let mut b = CsrBuilder::new(n);
        for s in 0..n as VertexId {
            for &t in self.neighbors(s) {
                b.add_edge(t, s);
                b.add_edge(s, t);
            }
        }
        b.dedup = true;
        b.finish()
    }

    /// Uniformly random existing edge `(t -> s)`; used by the
    /// edge-prediction workload generator.
    pub fn random_edge(&self, rng: &mut Pcg64) -> (VertexId, VertexId) {
        debug_assert!(self.num_edges() > 0);
        let e = rng.next_below(self.num_edges() as u64) as usize;
        // binary search for the destination owning edge slot e
        let s = match self.indptr.binary_search(&(e as u64)) {
            Ok(mut i) => {
                // land on the first vertex whose range starts at e and is non-empty
                while (i + 1) < self.indptr.len() && self.indptr[i + 1] == e as u64 {
                    i += 1;
                }
                i
            }
            Err(i) => i - 1,
        };
        (self.indices[e], s as VertexId)
    }

    /// True if `(t -> s)` exists (binary search; neighbor lists are sorted
    /// by the builder).
    pub fn has_edge(&self, t: VertexId, s: VertexId) -> bool {
        self.neighbors(s).binary_search(&t).is_ok()
    }
}

/// Incremental builder accumulating (dst, src) pairs, producing sorted
/// (optionally deduplicated) CSR.
#[derive(Clone, Debug)]
pub struct CsrBuilder {
    n: usize,
    /// (dst, src) pairs.
    pairs: Vec<(VertexId, VertexId)>,
    /// Deduplicate parallel edges on finish.
    pub dedup: bool,
}

impl CsrBuilder {
    pub fn new(num_vertices: usize) -> Self {
        CsrBuilder { n: num_vertices, pairs: Vec::new(), dedup: false }
    }

    pub fn with_capacity(num_vertices: usize, num_edges: usize) -> Self {
        CsrBuilder { n: num_vertices, pairs: Vec::with_capacity(num_edges), dedup: false }
    }

    /// Record edge `t -> s` (message from t to s; stored under s).
    #[inline]
    pub fn add_edge(&mut self, t: VertexId, s: VertexId) {
        debug_assert!((t as usize) < self.n && (s as usize) < self.n);
        self.pairs.push((s, t));
    }

    pub fn num_pairs(&self) -> usize {
        self.pairs.len()
    }

    /// Sort, optionally dedup, and produce the CSR.
    pub fn finish(mut self) -> Csr {
        self.pairs.sort_unstable();
        if self.dedup {
            self.pairs.dedup();
        }
        let mut indptr = vec![0u64; self.n + 1];
        for &(s, _) in &self.pairs {
            indptr[s as usize + 1] += 1;
        }
        for i in 0..self.n {
            indptr[i + 1] += indptr[i];
        }
        let indices: Vec<VertexId> = self.pairs.iter().map(|&(_, t)| t).collect();
        Csr { indptr, indices }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Csr {
        // edges: 0->1, 1->2, 2->0 and 0->2
        let mut b = CsrBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        b.add_edge(0, 2);
        b.finish()
    }

    #[test]
    fn basic_shape() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(1), &[0]);
        let mut n2 = g.neighbors(2).to_vec();
        n2.sort_unstable();
        assert_eq!(n2, vec![0, 1]);
        assert_eq!(g.degree(0), 1);
        assert!((g.avg_degree() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn has_edge_works() {
        let g = triangle();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
        assert!(g.has_edge(1, 2));
    }

    #[test]
    fn reverse_round_trip() {
        let g = triangle();
        let r = g.reverse().reverse();
        assert_eq!(g.indptr, r.indptr);
        assert_eq!(g.indices, r.indices);
    }

    #[test]
    fn undirected_symmetric() {
        let g = triangle().to_undirected();
        for s in 0..3u32 {
            for &t in g.neighbors(s) {
                assert!(g.has_edge(s, t), "symmetry {t}<->{s}");
            }
        }
    }

    #[test]
    fn dedup_removes_parallel_edges() {
        let mut b = CsrBuilder::new(2);
        b.add_edge(0, 1);
        b.add_edge(0, 1);
        b.dedup = true;
        let g = b.finish();
        assert_eq!(g.num_edges(), 1);
    }

    #[test]
    fn random_edge_is_valid() {
        let g = triangle();
        let mut rng = Pcg64::new(1);
        for _ in 0..200 {
            let (t, s) = g.random_edge(&mut rng);
            assert!(g.has_edge(t, s), "({t}->{s}) must exist");
        }
    }

    #[test]
    fn random_edge_covers_all() {
        let g = triangle();
        let mut rng = Pcg64::new(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            seen.insert(g.random_edge(&mut rng));
        }
        assert_eq!(seen.len(), g.num_edges());
    }

    #[test]
    fn empty_graph() {
        let g = CsrBuilder::new(0).finish();
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.avg_degree(), 0.0);
    }
}
