//! Graph substrate: CSR storage, synthetic generators, partitioners, the
//! dataset registry mirroring the paper's Table 2 (scaled), and binary IO.
//!
//! Everything downstream (samplers, the cooperative engine, the repro
//! harnesses) consumes [`Csr`] through `neighbors()` / `degree()`; the
//! partitioners produce a [`partition::Partition`] mapping every vertex to
//! a PE, which is the 1-D partitioning of paper §3.1.

pub mod csr;
pub mod generate;
pub mod partition;
pub mod datasets;
pub mod io;

pub use csr::{Csr, CsrBuilder, VertexId};
pub use partition::Partition;
pub use datasets::Dataset;
