//! 1-D graph partitioning (paper §3.1: "we first partition the graph in 1D
//! fashion by logically assigning each vertex and its incoming edges to
//! PEs").
//!
//! Three quality tiers, matching what the paper evaluates in Table 7:
//!
//! * [`random`] — hash partitioning; cross-edge ratio `c ≈ (P-1)/P`.
//! * [`ldg`] — streaming Linear Deterministic Greedy; a cheap middle
//!   ground.
//! * [`multilevel`] — heavy-edge-matching coarsening + greedy growth +
//!   boundary refinement: our stand-in for METIS (the paper's partitioner).
//!   Only the resulting cross-edge ratio `c` and neighborhood overlap feed
//!   the experiments, so a METIS-quality-ish `c` is sufficient.

use super::csr::{Csr, VertexId};
use crate::util::rng::Pcg64;

/// A vertex -> PE assignment.
#[derive(Clone, Debug)]
pub struct Partition {
    pub assignment: Vec<u16>,
    pub num_parts: usize,
}

impl Partition {
    /// PE owning vertex `v`.
    #[inline]
    pub fn part_of(&self, v: VertexId) -> usize {
        self.assignment[v as usize] as usize
    }

    /// Vertices per part.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_parts];
        for &p in &self.assignment {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Fraction of edges whose endpoints live on different PEs — the `c`
    /// of the paper's Table 1 complexity model.
    pub fn cross_edge_ratio(&self, g: &Csr) -> f64 {
        if g.num_edges() == 0 {
            return 0.0;
        }
        let mut cross = 0usize;
        for s in 0..g.num_vertices() as VertexId {
            let ps = self.part_of(s);
            for &t in g.neighbors(s) {
                if self.part_of(t) != ps {
                    cross += 1;
                }
            }
        }
        cross as f64 / g.num_edges() as f64
    }

    /// Load imbalance: max part size / ideal part size.
    pub fn imbalance(&self) -> f64 {
        let sizes = self.part_sizes();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let ideal = self.assignment.len() as f64 / self.num_parts as f64;
        if ideal == 0.0 { 1.0 } else { max / ideal }
    }

    /// Vertices owned by part `p`, in id order.
    pub fn members(&self, p: usize) -> Vec<VertexId> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a as usize == p)
            .map(|(v, _)| v as VertexId)
            .collect()
    }
}

/// Hash/random partitioning.
pub fn random(g: &Csr, num_parts: usize, seed: u64) -> Partition {
    let mut rng = Pcg64::new(seed);
    let assignment = (0..g.num_vertices())
        .map(|_| rng.next_below(num_parts as u64) as u16)
        .collect();
    Partition { assignment, num_parts }
}

/// Contiguous range partitioning (useful as a baseline when vertex ids
/// carry locality, e.g. R-MAT before relabeling).
pub fn range(g: &Csr, num_parts: usize) -> Partition {
    let n = g.num_vertices();
    let assignment = (0..n)
        .map(|v| ((v * num_parts) / n.max(1)).min(num_parts - 1) as u16)
        .collect();
    Partition { assignment, num_parts }
}

/// Streaming Linear Deterministic Greedy: each vertex goes to the part
/// holding most of its (already-assigned) neighbors, damped by a load
/// penalty `(1 - size/capacity)`.
pub fn ldg(g: &Csr, num_parts: usize, seed: u64) -> Partition {
    let n = g.num_vertices();
    let capacity = (n as f64 / num_parts as f64) * 1.05 + 1.0;
    let mut assignment = vec![u16::MAX; n];
    let mut sizes = vec![0usize; num_parts];
    let mut order: Vec<u32> = (0..n as u32).collect();
    Pcg64::new(seed).shuffle(&mut order);
    let mut nbr_counts = vec![0u32; num_parts];
    for &v in &order {
        for c in nbr_counts.iter_mut() {
            *c = 0;
        }
        for &t in g.neighbors(v) {
            let a = assignment[t as usize];
            if a != u16::MAX {
                nbr_counts[a as usize] += 1;
            }
        }
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for p in 0..num_parts {
            if (sizes[p] as f64) >= capacity {
                continue;
            }
            // load penalty both scales the neighbor affinity and breaks
            // zero-affinity ties toward the lightest part
            let load = 1.0 - sizes[p] as f64 / capacity;
            let score = nbr_counts[p] as f64 * load + 1e-3 * load;
            if score > best_score {
                best_score = score;
                best = p;
            }
        }
        assignment[v as usize] = best as u16;
        sizes[best] += 1;
    }
    Partition { assignment, num_parts }
}

/// Multilevel partitioning: heavy-edge-matching coarsening until the graph
/// is small, greedy BFS-growth initial partitioning, then projected back
/// with a boundary-refinement (FM-lite) pass per level.
pub fn multilevel(g: &Csr, num_parts: usize, seed: u64) -> Partition {
    const COARSE_TARGET: usize = 2048;
    let mut rng = Pcg64::new(seed);

    // --- Coarsening ---------------------------------------------------
    // levels[i] = mapping from level-i vertex to level-(i+1) coarse vertex
    let mut graphs: Vec<Csr> = vec![symmetrize(g)];
    let mut maps: Vec<Vec<u32>> = Vec::new();
    while graphs.last().unwrap().num_vertices() > COARSE_TARGET.max(num_parts * 8) {
        let cur = graphs.last().unwrap();
        let (coarse, map) = coarsen_hem(cur, &mut rng);
        // Stop if coarsening stalls (matching shrank < 10%).
        if coarse.num_vertices() as f64 > cur.num_vertices() as f64 * 0.95 {
            break;
        }
        graphs.push(coarse);
        maps.push(map);
    }

    // --- Initial partitioning on the coarsest graph --------------------
    let coarsest = graphs.last().unwrap();
    let mut assignment = greedy_growth(coarsest, num_parts, &mut rng);
    refine(coarsest, &mut assignment, num_parts, 4);

    // --- Uncoarsen + refine --------------------------------------------
    for level in (0..maps.len()).rev() {
        let fine = &graphs[level];
        let map = &maps[level];
        let mut fine_assignment = vec![0u16; fine.num_vertices()];
        for v in 0..fine.num_vertices() {
            fine_assignment[v] = assignment[map[v] as usize];
        }
        assignment = fine_assignment;
        refine(fine, &mut assignment, num_parts, 2);
    }

    Partition { assignment, num_parts }
}

/// Union of in+out neighborhoods — partition quality should ignore edge
/// direction.
fn symmetrize(g: &Csr) -> Csr {
    g.to_undirected()
}

/// One round of heavy-edge matching: visit vertices in random order, match
/// each unmatched vertex with its most-connected unmatched neighbor
/// (multi-edges from symmetrize() act as weights via repetition counting).
fn coarsen_hem(g: &Csr, rng: &mut Pcg64) -> (Csr, Vec<u32>) {
    let n = g.num_vertices();
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut next_coarse = 0u32;
    let mut coarse_of = vec![u32::MAX; n];
    for &v in &order {
        if coarse_of[v as usize] != u32::MAX {
            continue;
        }
        // count multiplicity to emulate edge weights
        let mut best: Option<(u32, u32)> = None; // (count, nbr)
        let nbrs = g.neighbors(v);
        let mut i = 0;
        while i < nbrs.len() {
            let u = nbrs[i];
            let mut cnt = 1u32;
            while i + 1 < nbrs.len() && nbrs[i + 1] == u {
                cnt += 1;
                i += 1;
            }
            if u != v && coarse_of[u as usize] == u32::MAX {
                if best.map_or(true, |(bc, _)| cnt > bc) {
                    best = Some((cnt, u));
                }
            }
            i += 1;
        }
        let c = next_coarse;
        next_coarse += 1;
        coarse_of[v as usize] = c;
        if let Some((_, u)) = best {
            coarse_of[u as usize] = c;
            matched[v as usize] = u;
            matched[u as usize] = v;
        }
    }
    // Build the coarse graph.
    let mut b = super::csr::CsrBuilder::new(next_coarse as usize);
    for s in 0..n as VertexId {
        let cs = coarse_of[s as usize];
        for &t in g.neighbors(s) {
            let ct = coarse_of[t as usize];
            if cs != ct {
                b.add_edge(ct, cs);
            }
        }
    }
    (b.finish(), coarse_of)
}

/// Greedy BFS growth: pick P random roots, grow regions breadth-first,
/// assigning unclaimed vertices round-robin across frontiers.
fn greedy_growth(g: &Csr, num_parts: usize, rng: &mut Pcg64) -> Vec<u16> {
    let n = g.num_vertices();
    let mut assignment = vec![u16::MAX; n];
    let cap = n / num_parts + 1;
    let mut sizes = vec![0usize; num_parts];
    let mut frontiers: Vec<std::collections::VecDeque<u32>> = (0..num_parts)
        .map(|_| std::collections::VecDeque::new())
        .collect();
    for p in 0..num_parts {
        // find an unassigned random root
        for _ in 0..64 {
            let r = rng.next_below(n as u64) as usize;
            if assignment[r] == u16::MAX {
                assignment[r] = p as u16;
                sizes[p] += 1;
                frontiers[p].push_back(r as u32);
                break;
            }
        }
    }
    let mut remaining: Vec<u32> =
        (0..n as u32).filter(|&v| assignment[v as usize] == u16::MAX).collect();
    let mut active = true;
    while active {
        active = false;
        for p in 0..num_parts {
            if sizes[p] >= cap {
                continue;
            }
            if let Some(v) = frontiers[p].pop_front() {
                active = true;
                for &u in g.neighbors(v) {
                    if assignment[u as usize] == u16::MAX && sizes[p] < cap {
                        assignment[u as usize] = p as u16;
                        sizes[p] += 1;
                        frontiers[p].push_back(u);
                    }
                }
            }
        }
    }
    // Disconnected leftovers: round-robin into the lightest parts.
    remaining.retain(|&v| assignment[v as usize] == u16::MAX);
    for v in remaining {
        let p = sizes
            .iter()
            .enumerate()
            .min_by_key(|(_, &s)| s)
            .map(|(i, _)| i)
            .unwrap();
        assignment[v as usize] = p as u16;
        sizes[p] += 1;
    }
    assignment
}

/// Boundary refinement: move a vertex to the neighboring part with maximal
/// gain (external - internal edges) if balance allows. `passes` sweeps.
fn refine(g: &Csr, assignment: &mut [u16], num_parts: usize, passes: usize) {
    let n = g.num_vertices();
    let cap = (n as f64 / num_parts as f64 * 1.03) as usize + 1;
    let mut sizes = vec![0usize; num_parts];
    for &a in assignment.iter() {
        sizes[a as usize] += 1;
    }
    let mut counts = vec![0i64; num_parts];
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n as VertexId {
            let cur = assignment[v as usize] as usize;
            for c in counts.iter_mut() {
                *c = 0;
            }
            for &t in g.neighbors(v) {
                counts[assignment[t as usize] as usize] += 1;
            }
            let (mut best, mut best_gain) = (cur, 0i64);
            for p in 0..num_parts {
                if p == cur || sizes[p] >= cap {
                    continue;
                }
                let gain = counts[p] - counts[cur];
                if gain > best_gain {
                    best_gain = gain;
                    best = p;
                }
            }
            if best != cur {
                assignment[v as usize] = best as u16;
                sizes[cur] -= 1;
                sizes[best] += 1;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn test_graph() -> Csr {
        // community structure so partitioners have something to find
        // (pure Chung–Lu is an expander — even METIS barely beats random)
        generate::community(4000, 8.0, 2.5, 16, 0.8, 17)
    }

    #[test]
    fn random_is_balanced_and_covers() {
        let g = test_graph();
        let p = random(&g, 4, 1);
        assert_eq!(p.assignment.len(), g.num_vertices());
        assert!(p.imbalance() < 1.15, "imbalance {}", p.imbalance());
        let c = p.cross_edge_ratio(&g);
        assert!((c - 0.75).abs() < 0.05, "random c ≈ (P-1)/P, got {c}");
    }

    #[test]
    fn range_is_exact_cover() {
        let g = test_graph();
        let p = range(&g, 7);
        let sizes = p.part_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), g.num_vertices());
        assert!(p.imbalance() < 1.05);
    }

    #[test]
    fn ldg_beats_random() {
        let g = test_graph();
        let cr = random(&g, 4, 2).cross_edge_ratio(&g);
        let cl = ldg(&g, 4, 2).cross_edge_ratio(&g);
        assert!(cl < cr, "ldg {cl} should beat random {cr}");
    }

    #[test]
    fn multilevel_beats_random_and_balances() {
        let g = test_graph();
        let p = multilevel(&g, 4, 3);
        let cm = p.cross_edge_ratio(&g);
        let cr = random(&g, 4, 3).cross_edge_ratio(&g);
        assert!(cm < cr * 0.7, "multilevel {cm} vs random {cr}");
        assert!(p.imbalance() < 1.35, "imbalance {}", p.imbalance());
        assert_eq!(p.part_sizes().iter().sum::<usize>(), g.num_vertices());
    }

    #[test]
    fn members_partition_the_vertex_set() {
        let g = test_graph();
        let p = multilevel(&g, 3, 5);
        let mut all: Vec<u32> = (0..3).flat_map(|q| p.members(q)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..g.num_vertices() as u32).collect::<Vec<_>>());
    }

    #[test]
    fn single_part_degenerate() {
        let g = test_graph();
        let p = random(&g, 1, 9);
        assert_eq!(p.cross_edge_ratio(&g), 0.0);
        assert_eq!(p.imbalance(), 1.0);
    }
}
