//! Synthetic dataset registry.
//!
//! The paper evaluates on reddit / yelp / flickr / papers100M / mag240M
//! (its Table 2). Those datasets are not available offline, so each entry
//! here is a *scaled synthetic twin*: a Chung–Lu power-law graph matched to
//! the original's average degree and split percentages, with |V| scaled to
//! CPU-simulation size. Cache sizes keep the original cache/|S³| *pressure*
//! ratio (see [`Spec::cache_s3_ratio`]), so the LRU-miss-rate experiments
//! (paper Fig. 5) sit in the same regime.
//!
//! Features are **hash-generated on demand** (O(1) storage; see
//! [`Dataset::write_features`]) and labels come from a **planted 1-hop
//! teacher**: `y(v) = argmax_c  w_c · mean_{u ∈ N(v) ∪ {v}} x_u` with label
//! noise. Node classification on this target is learnable by a GCN but not
//! by a featureless or graph-free model, giving meaningful convergence
//! curves for the κ-dependence and coop-vs-indep experiments
//! (paper Table 3, Figures 4/8/9).

use super::csr::{Csr, VertexId};
use super::generate;
use crate::util::rng::{counter_hash2, counter_hash3, Pcg64};

/// A fully materialized synthetic dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub labels: Vec<u16>,
    pub train: Vec<VertexId>,
    pub val: Vec<VertexId>,
    pub test: Vec<VertexId>,
    /// LRU capacity for vertex-embedding caching (paper Table 2 ratio).
    pub cache_size: usize,
    feat_seed: u64,
}

/// Registry entry: the recipe for a dataset twin.
#[derive(Clone, Debug)]
pub struct Spec {
    pub name: &'static str,
    /// Description of which paper dataset this mirrors.
    pub mirrors: &'static str,
    pub num_vertices: usize,
    pub avg_degree: f64,
    pub gamma: f64,
    pub feat_dim: usize,
    pub num_classes: usize,
    /// (train, val, test) percentages, paper Table 2.
    pub split: (f64, f64, f64),
    /// LRU capacity as a multiple of one batch's |S³| (b=1024, LABOR-0).
    ///
    /// The paper sizes caches in absolute rows (Table 2); what governs
    /// the miss-rate dynamics of Figure 5 is the *cache pressure* —
    /// capacity relative to the per-batch working set. Scaling |V| down
    /// while keeping b=1024 would break that regime if we scaled the
    /// cache by |V|, so the twins anchor capacity to the measured |S³|
    /// with the paper's cache/|S³| ratios (papers100M: 2M/463k ≈ 4.3,
    /// mag: 2M/443k ≈ 4.5, reddit: 60k/37k ≈ 1.6, flickr ≈ 1.4,
    /// yelp ≈ 1.3 — Tables 2/7).
    pub cache_s3_ratio: f64,
    pub undirected: bool,
    /// planted community structure `(blocks, p_in)` — citation-network
    /// twins (papers/mag) get this so graph partitioning has something to
    /// cut, like the paper's METIS rows in Table 7 (pure Chung–Lu is an
    /// expander; real citation graphs cluster by field).
    pub community: Option<(usize, f64)>,
}

/// The registry. Scale factors vs the paper: flickr 1:1, yelp 1:5,
/// reddit 1:4 with degree clipped to 120 (CPU memory), papers100M 1:500,
/// mag240M 1:1000. Two extra entries support tests (`tiny`) and the
/// convergence studies (`conv`).
#[rustfmt::skip]
pub const SPECS: &[Spec] = &[
    // (tabular on purpose — one registry row per line beats rustfmt's
    // exploded struct literals for scanning the corpus side by side)
    Spec { name: "flickr-s", mirrors: "flickr (1:1)", num_vertices: 89_200, avg_degree: 10.09, gamma: 2.5, feat_dim: 500, num_classes: 7, split: (0.50, 0.25, 0.25), cache_s3_ratio: 1.4, undirected: false, community: None },
    Spec { name: "yelp-s", mirrors: "yelp (1:5)", num_vertices: 143_400, avg_degree: 19.52, gamma: 2.4, feat_dim: 300, num_classes: 16, split: (0.75, 0.10, 0.15), cache_s3_ratio: 1.3, undirected: false, community: None },
    Spec { name: "reddit-s", mirrors: "reddit (1:1 vertices, degree clipped 493→120)", num_vertices: 233_000, avg_degree: 120.0, gamma: 2.2, feat_dim: 602, num_classes: 41, split: (0.66, 0.10, 0.24), cache_s3_ratio: 1.6, undirected: false, community: None },
    Spec { name: "papers-s", mirrors: "ogbn-papers100M (1:500)", num_vertices: 222_000, avg_degree: 29.10, gamma: 2.4, feat_dim: 128, num_classes: 32, split: (0.10, 0.011, 0.019), cache_s3_ratio: 4.3, undirected: true, community: Some((64, 0.6)) },
    Spec { name: "mag-s", mirrors: "mag240M (1:1000)", num_vertices: 244_000, avg_degree: 14.16, gamma: 2.4, feat_dim: 768, num_classes: 64, split: (0.08, 0.006, 0.004), cache_s3_ratio: 4.5, undirected: true, community: Some((64, 0.6)) },
    Spec { name: "conv", mirrors: "convergence-study twin (small, dense splits)", num_vertices: 12_000, avg_degree: 12.0, gamma: 2.4, feat_dim: 64, num_classes: 16, split: (0.50, 0.20, 0.30), cache_s3_ratio: 1.5, undirected: true, community: None },
    Spec { name: "tiny", mirrors: "test fixture", num_vertices: 2_000, avg_degree: 8.0, gamma: 2.5, feat_dim: 16, num_classes: 8, split: (0.5, 0.2, 0.3), cache_s3_ratio: 1.5, undirected: true, community: None },
];

/// Look up a spec by name.
pub fn spec(name: &str) -> Option<&'static Spec> {
    SPECS.iter().find(|s| s.name == name)
}

/// Build a dataset by registry name. `seed` controls all randomness
/// (graph, labels, splits); the same (name, seed) is bit-reproducible.
pub fn build(name: &str, seed: u64) -> crate::Result<Dataset> {
    let sp = spec(name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset `{name}`; known: {:?}",
            SPECS.iter().map(|s| s.name).collect::<Vec<_>>()))?;
    Ok(build_from_spec(sp, seed))
}

/// Build from an explicit spec (used by tests with custom sizes).
pub fn build_from_spec(sp: &Spec, seed: u64) -> Dataset {
    let mut g = match sp.community {
        Some((blocks, p_in)) => {
            generate::community(sp.num_vertices, sp.avg_degree, sp.gamma, blocks, p_in, seed ^ 0xD5)
        }
        None => generate::chung_lu(sp.num_vertices, sp.avg_degree, sp.gamma, seed ^ 0xD5),
    };
    if sp.undirected {
        g = g.to_undirected();
    }
    let feat_seed = seed ^ 0xFEA7;
    let labels = plant_labels(&g, sp, feat_seed, seed ^ 0x1AB5);
    let (train, val, test) = make_splits(sp, g.num_vertices(), seed ^ 0x5B11);
    let cache_size = probe_cache_size(&g, sp, seed);
    Dataset {
        name: sp.name.to_string(),
        graph: g,
        feat_dim: sp.feat_dim,
        num_classes: sp.num_classes,
        labels,
        train,
        val,
        test,
        cache_size,
        feat_seed,
    }
}

/// Anchor the LRU capacity to the measured per-batch working set: sample
/// one reference MFG (LABOR-0, L=3, k=10, b=min(1024, |V|/2)) and apply
/// the spec's cache/|S³| ratio, clamped to `[0.05·|V|, 0.8·|V|]` — the
/// twins' L-hop expansions cover a larger |V| fraction than the paper's
/// giant graphs, so an unclamped ratio could exceed |V| (trivially zero
/// misses) or starve the cache into pure scan-thrash; the clamp keeps
/// every twin inside the regime where Figure 5's dynamics live.
fn probe_cache_size(g: &Csr, sp: &Spec, seed: u64) -> usize {
    use crate::sampling::{SamplerConfig, SamplerKind};
    let n = g.num_vertices();
    let b = 1024.min(n / 2).max(8);
    let cfg = SamplerConfig::default();
    let mut sampler = cfg.build(SamplerKind::Labor0, g, seed ^ 0xCACE);
    let mut rng = Pcg64::new(seed ^ 0x5EEE);
    let seeds: Vec<VertexId> = rng.sample_distinct(n, b);
    let s3 = sampler.sample_mfg(&seeds).input_vertices().len();
    let raw = (s3 as f64) * sp.cache_s3_ratio;
    raw.clamp(0.05 * n as f64, 0.80 * n as f64) as usize
}

impl Dataset {
    /// Write the feature vector of `v` into `out` (len = feat_dim).
    /// Features are iid U(-1, 1) derived from a counter hash — free to
    /// "store", deterministic to regenerate, identical across PEs.
    #[inline]
    pub fn write_features(&self, v: VertexId, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.feat_dim);
        for (j, o) in out.iter_mut().enumerate() {
            *o = feat_value(self.feat_seed, v, j as u64);
        }
    }

    /// Materialize features for a list of vertices into a flat row-major
    /// buffer. Hash-generation fallback only — the pipeline's hot paths
    /// read materialized rows through
    /// [`crate::feature::FeatureStore::gather`] instead, so gathered
    /// bytes are accounted as real storage traffic.
    pub fn gather_features(&self, vs: &[VertexId], out: &mut Vec<f32>) {
        out.clear();
        out.resize(vs.len() * self.feat_dim, 0.0);
        for (i, &v) in vs.iter().enumerate() {
            let row = &mut out[i * self.feat_dim..(i + 1) * self.feat_dim];
            self.write_features(v, row);
        }
    }

    /// Bytes of one *decoded* vertex embedding row (`feat_dim` f32s).
    /// This is the in-memory size a consumer sees after a gather; the
    /// *wire* size charged to the storage/fabric byte ledgers comes from
    /// the serving store's codec
    /// ([`crate::feature::FeatureStore::row_bytes`]) and is smaller
    /// under fp16/int8 compression.
    pub fn row_bytes(&self) -> usize {
        self.feat_dim * 4
    }

    pub fn label(&self, v: VertexId) -> u16 {
        self.labels[v as usize]
    }
}

#[inline]
fn feat_value(seed: u64, v: VertexId, j: u64) -> f32 {
    let h = counter_hash3(seed, v as u64, j);
    ((h >> 40) as f32) * (2.0 / (1u64 << 24) as f32) - 1.0
}

/// Planted teacher labels: project each vertex's 1-hop mean-aggregated
/// feature through a random class matrix, take the argmax, flip 10% of
/// labels uniformly (noise floor so 100% accuracy is unreachable).
fn plant_labels(g: &Csr, sp: &Spec, feat_seed: u64, label_seed: u64) -> Vec<u16> {
    let n = g.num_vertices();
    let d = sp.feat_dim;
    let c = sp.num_classes;
    let mut rng = Pcg64::new(label_seed);
    // Random class projection with unit-ish rows.
    let mut w = vec![0f32; c * d];
    for x in w.iter_mut() {
        *x = rng.next_normal() as f32 / (d as f32).sqrt();
    }
    let mut labels = vec![0u16; n];
    let mut agg = vec![0f32; d];
    let mut tmp = vec![0f32; d];
    // Cap the teacher's neighborhood at 16 deterministic samples per
    // vertex: the teacher stays structure-dependent while label planting
    // stays O(|V|·16·d) instead of O(|E|·d) (reddit-s has 28M edges).
    const TEACHER_CAP: usize = 16;
    for v in 0..n as VertexId {
        // mean over sampled(N(v)) ∪ {v}
        for a in agg.iter_mut() {
            *a = 0.0;
        }
        let nbrs = g.neighbors(v);
        let step = (nbrs.len() / TEACHER_CAP).max(1);
        let mut used = 0usize;
        let mut i = (v as usize) % step; // deterministic stagger
        while i < nbrs.len() && used < TEACHER_CAP {
            let t = nbrs[i];
            for j in 0..d {
                tmp[j] = feat_value(feat_seed, t, j as u64);
            }
            for j in 0..d {
                agg[j] += tmp[j];
            }
            used += 1;
            i += step;
        }
        for j in 0..d {
            agg[j] += feat_value(feat_seed, v, j as u64);
        }
        let inv = 1.0 / (used as f32 + 1.0);
        let mut best = 0usize;
        let mut best_score = f32::NEG_INFINITY;
        for cls in 0..c {
            let row = &w[cls * d..(cls + 1) * d];
            let score: f32 = row.iter().zip(agg.iter()).map(|(a, b)| a * b * inv).sum();
            if score > best_score {
                best_score = score;
                best = cls;
            }
        }
        // 5% label noise (keeps a noise floor without hiding convergence
        // differences in the κ ablations)
        labels[v as usize] = if u64_noise(label_seed, v) < 0.05 {
            Pcg64::new(counter_hash2(label_seed, v as u64)).next_below(c as u64) as u16
        } else {
            best as u16
        };
    }
    labels
}

#[inline]
fn u64_noise(seed: u64, v: VertexId) -> f64 {
    crate::util::rng::u64_to_unit_f64(counter_hash2(seed ^ 0x901, v as u64))
}

fn make_splits(sp: &Spec, n: usize, seed: u64) -> (Vec<VertexId>, Vec<VertexId>, Vec<VertexId>) {
    let mut order: Vec<VertexId> = (0..n as VertexId).collect();
    Pcg64::new(seed).shuffle(&mut order);
    let (tr, va, te) = sp.split;
    let n_tr = ((n as f64) * tr).round() as usize;
    let n_va = ((n as f64) * va).round() as usize;
    let n_te = ((n as f64) * te).round() as usize;
    let train = order[..n_tr].to_vec();
    let val = order[n_tr..n_tr + n_va].to_vec();
    let test = order[n_tr + n_va..(n_tr + n_va + n_te).min(n)].to_vec();
    (train, val, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique() {
        let mut names: Vec<_> = SPECS.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), SPECS.len());
    }

    #[test]
    fn tiny_builds_and_is_consistent() {
        let ds = build("tiny", 1).unwrap();
        assert_eq!(ds.graph.num_vertices(), 2000);
        assert_eq!(ds.labels.len(), 2000);
        assert!(ds.labels.iter().all(|&l| (l as usize) < ds.num_classes));
        // splits are disjoint
        let mut all: Vec<u32> = ds
            .train
            .iter()
            .chain(ds.val.iter())
            .chain(ds.test.iter())
            .copied()
            .collect();
        let total = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total, "splits must be disjoint");
    }

    #[test]
    fn features_deterministic_and_bounded() {
        let ds = build("tiny", 2).unwrap();
        let mut a = vec![0f32; ds.feat_dim];
        let mut b = vec![0f32; ds.feat_dim];
        ds.write_features(5, &mut a);
        ds.write_features(5, &mut b);
        assert_eq!(a, b);
        ds.write_features(6, &mut b);
        assert_ne!(a, b);
        assert!(a.iter().all(|x| (-1.0..=1.0).contains(x)));
    }

    #[test]
    fn gather_features_layout() {
        let ds = build("tiny", 3).unwrap();
        let mut buf = Vec::new();
        ds.gather_features(&[3, 9], &mut buf);
        assert_eq!(buf.len(), 2 * ds.feat_dim);
        let mut row = vec![0f32; ds.feat_dim];
        ds.write_features(9, &mut row);
        assert_eq!(&buf[ds.feat_dim..], &row[..]);
    }

    #[test]
    fn labels_have_structure_not_uniform() {
        // The planted teacher must produce a class distribution measurably
        // different from uniform noise (it projects a smooth aggregate).
        let ds = build("tiny", 4).unwrap();
        let mut counts = vec![0usize; ds.num_classes];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 1.2, "teacher classes should be skewed: {counts:?}");
    }

    #[test]
    fn same_seed_same_dataset() {
        let a = build("tiny", 7).unwrap();
        let b = build("tiny", 7).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.train, b.train);
        assert_eq!(a.graph.indices, b.graph.indices);
    }

    #[test]
    fn conv_split_sizes() {
        let ds = build("conv", 5).unwrap();
        let n = ds.graph.num_vertices() as f64;
        assert!((ds.train.len() as f64 / n - 0.5).abs() < 0.01);
        assert!((ds.val.len() as f64 / n - 0.2).abs() < 0.01);
    }
}
