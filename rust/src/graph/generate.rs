//! Synthetic graph generators.
//!
//! The paper's datasets are power-law web/social graphs (its §1 cites
//! Artico et al. on power-law prevalence); the monotonicity/concavity
//! phenomena it studies depend only on the degree distribution and the
//! overlap structure of L-hop neighborhoods. We provide:
//!
//! * [`chung_lu`] — expected-degree model with a Pareto weight sequence:
//!   the workhorse for the dataset registry (controllable |V|, avg degree,
//!   and tail exponent).
//! * [`rmat`] — Kronecker-style recursive matrix generator (Graph500
//!   defaults), for skewed, community-ish structure.
//! * [`erdos_renyi`] — flat-degree control case (work curves should be
//!   much less concave: minimal neighborhood overlap).
//! * [`preferential_attachment`] — Barabási–Albert, as a second heavy-tail
//!   family for robustness checks.

use super::csr::{Csr, CsrBuilder, VertexId};
use crate::util::rng::Pcg64;

/// Chung–Lu expected-degree graph.
///
/// Vertex weights follow a Pareto law `w_i ∝ (i + i0)^(-1/(gamma-1))`
/// normalized so the expected number of directed edges is
/// `n * avg_degree`. Edges are drawn by sampling endpoint pairs
/// proportionally to weight (cumulative-table inversion), which yields the
/// classic power-law degree distribution with exponent `gamma`.
pub fn chung_lu(n: usize, avg_degree: f64, gamma: f64, seed: u64) -> Csr {
    assert!(n > 1 && avg_degree > 0.0 && gamma > 2.0);
    let mut rng = Pcg64::new(seed);
    let m = (n as f64 * avg_degree) as usize;
    // Pareto weights; i0 shifts the head so the max degree stays bounded.
    let alpha = 1.0 / (gamma - 1.0);
    let i0 = 10.0_f64.max(n as f64 * 0.001);
    let mut weights = Vec::with_capacity(n);
    for i in 0..n {
        weights.push((i as f64 + i0).powf(-alpha));
    }
    // Shuffle weight-to-id assignment so vertex ids carry no degree info.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    // Cumulative table over the *unshuffled* weights; map through perm.
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for w in &weights {
        acc += w;
        cum.push(acc);
    }
    let total = acc;
    let draw = |rng: &mut Pcg64| -> VertexId {
        let x = rng.next_f64() * total;
        let idx = match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i,
        };
        perm[idx.min(n - 1)]
    };
    let mut b = CsrBuilder::with_capacity(n, m);
    b.dedup = true;
    let mut added = 0usize;
    // Sample a few more than m to compensate for dedup + self-loop rejects.
    let budget = m + m / 8 + 16;
    for _ in 0..budget {
        let t = draw(&mut rng);
        let s = draw(&mut rng);
        if t == s {
            continue;
        }
        b.add_edge(t, s);
        added += 1;
        if added >= budget {
            break;
        }
    }
    b.finish()
}

/// R-MAT generator (recursive quadrant descent with probabilities
/// a, b, c, d; Graph500 uses 0.57/0.19/0.19/0.05). `scale` gives
/// `n = 2^scale` vertices; `edge_factor` gives `m = n * edge_factor`.
pub fn rmat(scale: u32, edge_factor: usize, probs: (f64, f64, f64, f64), seed: u64) -> Csr {
    let (a, b_, c, d) = probs;
    assert!((a + b_ + c + d - 1.0).abs() < 1e-9);
    let n = 1usize << scale;
    let m = n * edge_factor;
    let mut rng = Pcg64::new(seed);
    // Random vertex relabeling kills the id-locality artifact of R-MAT.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    let mut builder = CsrBuilder::with_capacity(n, m);
    builder.dedup = true;
    for _ in 0..m {
        let (mut lo_t, mut lo_s) = (0usize, 0usize);
        let mut half = n >> 1;
        while half > 0 {
            // Noise the quadrant probabilities slightly per level (standard
            // trick avoiding exact self-similarity artifacts).
            let u = rng.next_f64();
            let (dt, ds) = if u < a {
                (0, 0)
            } else if u < a + b_ {
                (0, 1)
            } else if u < a + b_ + c {
                (1, 0)
            } else {
                (1, 1)
            };
            lo_t += dt * half;
            lo_s += ds * half;
            half >>= 1;
        }
        if lo_t != lo_s {
            builder.add_edge(perm[lo_t], perm[lo_s]);
        }
    }
    builder.finish()
}

/// Erdős–Rényi G(n, m): m uniform random directed edges, no self loops.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut b = CsrBuilder::with_capacity(n, m);
    b.dedup = true;
    let mut added = 0;
    while added < m {
        let t = rng.next_below(n as u64) as VertexId;
        let s = rng.next_below(n as u64) as VertexId;
        if t == s {
            continue;
        }
        b.add_edge(t, s);
        added += 1;
    }
    b.finish()
}

/// Barabási–Albert preferential attachment: each new vertex attaches
/// `m_per` edges to existing vertices chosen ∝ degree (implemented with
/// the repeated-endpoint list trick). Edges are stored in both directions
/// (BA is an undirected model), so hubs carry large in-neighborhoods.
pub fn preferential_attachment(n: usize, m_per: usize, seed: u64) -> Csr {
    assert!(n > m_per && m_per >= 1);
    let mut rng = Pcg64::new(seed);
    let mut endpoint_pool: Vec<VertexId> = Vec::with_capacity(2 * n * m_per);
    let mut b = CsrBuilder::with_capacity(n, 2 * n * m_per);
    b.dedup = true;
    // Seed clique among the first m_per+1 vertices.
    for v in 0..=(m_per as VertexId) {
        for u in 0..v {
            b.add_edge(u, v);
            b.add_edge(v, u);
            endpoint_pool.push(u);
            endpoint_pool.push(v);
        }
    }
    for v in (m_per + 1)..n {
        for _ in 0..m_per {
            let u = endpoint_pool[rng.next_below(endpoint_pool.len() as u64) as usize];
            if u == v as VertexId {
                continue;
            }
            b.add_edge(u, v as VertexId);
            b.add_edge(v as VertexId, u);
            endpoint_pool.push(u);
            endpoint_pool.push(v as VertexId);
        }
    }
    b.finish()
}

/// Power-law graph with planted community structure: vertices are split
/// into `blocks` equal communities; each sampled edge keeps both endpoints
/// in one community with probability `p_in` (otherwise endpoints are
/// drawn globally). Degrees still follow the Chung–Lu Pareto law. This is
/// what makes the paper's partitioning experiments (Table 7 `metis` rows)
/// meaningful: pure Chung–Lu graphs are expanders with nothing to cut.
pub fn community(
    n: usize,
    avg_degree: f64,
    gamma: f64,
    blocks: usize,
    p_in: f64,
    seed: u64,
) -> Csr {
    assert!(n > 1 && blocks >= 1 && (0.0..=1.0).contains(&p_in));
    let mut rng = Pcg64::new(seed);
    let m = (n as f64 * avg_degree) as usize;
    let alpha = 1.0 / (gamma - 1.0);
    let i0 = 10.0_f64.max(n as f64 * 0.001);
    // Per-block weight tables; vertex v belongs to block v % blocks so the
    // within-block cumulative tables stay contiguous.
    let block_of = |v: usize| v % blocks;
    let mut weights = Vec::with_capacity(n);
    for i in 0..n {
        weights.push((i as f64 + i0).powf(-alpha));
    }
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    // global cumulative
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for w in &weights {
        acc += w;
        cum.push(acc);
    }
    let total = acc;
    // per-block member lists + block cumulative over the same weights
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); blocks];
    for i in 0..n {
        members[block_of(perm[i] as usize)].push(i as u32); // store weight idx
    }
    let mut block_cum: Vec<Vec<f64>> = Vec::with_capacity(blocks);
    let mut block_tot: Vec<f64> = Vec::with_capacity(blocks);
    for b in 0..blocks {
        let mut c = Vec::with_capacity(members[b].len());
        let mut a = 0.0;
        for &wi in &members[b] {
            a += weights[wi as usize];
            c.push(a);
        }
        block_cum.push(c);
        block_tot.push(a);
    }
    let draw_global = |rng: &mut Pcg64| -> VertexId {
        let x = rng.next_f64() * total;
        let idx = cum.partition_point(|&c| c < x);
        perm[idx.min(n - 1)]
    };
    let draw_in_block = |rng: &mut Pcg64, b: usize| -> VertexId {
        let x = rng.next_f64() * block_tot[b];
        let idx = block_cum[b].partition_point(|&c| c < x);
        perm[members[b][idx.min(members[b].len() - 1)] as usize]
    };
    let mut builder = CsrBuilder::with_capacity(n, m);
    builder.dedup = true;
    let budget = m + m / 8 + 16;
    for _ in 0..budget {
        let t = draw_global(&mut rng);
        let s = if rng.next_f64() < p_in {
            draw_in_block(&mut rng, block_of(t as usize))
        } else {
            draw_global(&mut rng)
        };
        if t != s {
            builder.add_edge(t, s);
        }
    }
    builder.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chung_lu_matches_target_size() {
        let g = chung_lu(2000, 8.0, 2.5, 42);
        assert_eq!(g.num_vertices(), 2000);
        let avg = g.avg_degree();
        assert!(avg > 5.0 && avg < 11.0, "avg degree {avg}");
    }

    #[test]
    fn chung_lu_heavy_tail() {
        let g = chung_lu(5000, 10.0, 2.3, 7);
        // Power-law: max degree far above average.
        assert!(g.max_degree() as f64 > 5.0 * g.avg_degree());
    }

    #[test]
    fn chung_lu_deterministic() {
        let a = chung_lu(500, 6.0, 2.5, 9);
        let b = chung_lu(500, 6.0, 2.5, 9);
        assert_eq!(a.indices, b.indices);
        let c = chung_lu(500, 6.0, 2.5, 10);
        assert_ne!(a.indices, c.indices);
    }

    #[test]
    fn rmat_shape() {
        let g = rmat(10, 8, (0.57, 0.19, 0.19, 0.05), 3);
        assert_eq!(g.num_vertices(), 1024);
        assert!(g.num_edges() > 1024 * 4, "edges {}", g.num_edges());
        assert!(g.max_degree() as f64 > 3.0 * g.avg_degree());
    }

    #[test]
    fn er_flat_degrees() {
        let g = erdos_renyi(2000, 16_000, 5);
        assert_eq!(g.num_vertices(), 2000);
        // ER max degree stays within a small factor of the mean.
        assert!((g.max_degree() as f64) < 4.0 * g.avg_degree() + 10.0);
    }

    #[test]
    fn ba_grows_connected_tail() {
        let g = preferential_attachment(1000, 4, 11);
        assert_eq!(g.num_vertices(), 1000);
        assert!(g.num_edges() >= 900 * 3);
        assert!(g.max_degree() as f64 > 4.0 * g.avg_degree());
    }

    #[test]
    fn no_self_loops_anywhere() {
        for g in [
            chung_lu(800, 6.0, 2.5, 1),
            rmat(9, 6, (0.57, 0.19, 0.19, 0.05), 2),
            erdos_renyi(800, 4000, 3),
            preferential_attachment(800, 3, 4),
        ] {
            for s in 0..g.num_vertices() as u32 {
                assert!(!g.neighbors(s).contains(&s), "self loop at {s}");
            }
        }
    }
}
