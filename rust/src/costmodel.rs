//! Bandwidth cost model — the paper's Table 1 complexity formulas with
//! the Table 4 hardware constants.
//!
//! The paper's runtime claims are driven by *counts* (per-PE sampled
//! vertices/edges, fabric traffic, cache misses) passed through three
//! bandwidths: γ (PE memory), α (inter-PE fabric / NVLink), β (storage /
//! PCI-e). We measure the identical counts with the simulation engine
//! ([`crate::coop::engine`]) on the synthetic dataset twins and estimate
//! per-stage times on the paper's three systems. Absolute milliseconds
//! are not expected to match the paper (different graphs, scaled sizes);
//! the *shape* — who wins, how the gap grows with P — is the
//! reproduction target (see EXPERIMENTS.md).
//!
//! | Stage            | Independent                      | Cooperative                                   |
//! |------------------|----------------------------------|-----------------------------------------------|
//! | Sampling         | O(|S^l(B/P)| / β)                | O(|S_p^l(B)|/β + |S̃_p^{l+1}(B)|·c/α)          |
//! | Feature loading  | O(|S^L(B/P)|·dρ/β)               | O(|S_p^L(B)|·dρ/β + |S̃_p^L(B)|·dc/α)          |
//! | Forward/Backward | O(M(S,E,S')·d/γ)                 | O(M(S_p,E_p,S̃_p)·d/γ + |S̃_p^{l+1}|·dc̃/α)     |

use crate::coop::all_to_all::{AllReduceStrategy, Topology};
use crate::coop::engine::EngineReport;

/// One link class of the two-level fabric: startup latency α (µs) and
/// sustained bandwidth (GB/s). The alpha-beta model prices one message
/// of `b` bytes at `α + b/bw`, the classic cost frame collective
/// algorithms are compared in.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCost {
    pub alpha_us: f64,
    pub gbps: f64,
}

impl LinkCost {
    /// Time to move `bytes` once over this link (µs).
    pub fn time_us(&self, bytes: f64) -> f64 {
        self.alpha_us + bytes / (self.gbps * 1e3)
    }
}

/// The two link classes of a replicated fabric ([`Topology`]): fast
/// NVLink-class links within a replica group, slow IB/PCIe-class links
/// between groups. Defaults follow the paper's Table 4 fast fabric
/// (600 GB/s) over a 100 GB/s inter-node class; `--intra-bw` /
/// `--inter-bw` override the bandwidths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricModel {
    pub intra: LinkCost,
    pub inter: LinkCost,
}

impl Default for FabricModel {
    fn default() -> Self {
        FabricModel {
            intra: LinkCost { alpha_us: 2.0, gbps: 600.0 },
            inter: LinkCost { alpha_us: 10.0, gbps: 100.0 },
        }
    }
}

impl FabricModel {
    /// Model with CLI-overridden bandwidths (GB/s); `None` keeps the
    /// class default. Latencies always keep their class defaults.
    pub fn with_bandwidths(intra_gbps: Option<f64>, inter_gbps: Option<f64>) -> FabricModel {
        let mut fm = FabricModel::default();
        if let Some(bw) = intra_gbps {
            fm.intra.gbps = bw;
        }
        if let Some(bw) = inter_gbps {
            fm.inter.gbps = bw;
        }
        fm
    }

    /// The link class an all-reduce is bound by under `topo`: a flat
    /// fabric runs entirely on the fast class, a replicated fabric is
    /// bound by the leader hops on the slow class.
    pub fn binding_link(&self, topo: &Topology) -> &LinkCost {
        if topo.replication > 1 {
            &self.inter
        } else {
            &self.intra
        }
    }
}

/// Ceil(log2 p) as f64 (0 for p ≤ 1).
fn ceil_log2(p: usize) -> f64 {
    if p <= 1 {
        0.0
    } else {
        (usize::BITS - (p - 1).leading_zeros()) as f64
    }
}

/// Modeled per-PE completion time (µs) of one all-reduce of `payload`
/// bytes among `p` participants over `link`, per strategy:
///
/// * `Naive`   — `α + (p−1)·b/bw`: one round of full-buffer sends.
/// * `Tree`    — `2⌈log₂p⌉·(α + b/bw)`: binomial gather + broadcast.
/// * `Ring`    — `2(p−1)·α + 2b(p−1)/(p·bw)`: bandwidth-optimal bytes,
///   linear latency.
/// * `Rsag`    — `2⌈log₂p⌉·α + 2b(p−1)/(p·bw)`: recursive
///   reduce-scatter/all-gather, bandwidth-optimal with log latency.
pub fn collective_time_us(
    strategy: AllReduceStrategy,
    p: usize,
    payload_bytes: u64,
    link: &LinkCost,
) -> f64 {
    let b = payload_bytes as f64;
    let pf = p as f64;
    let bw = link.gbps * 1e3; // bytes per µs
    let logp = ceil_log2(p);
    match strategy {
        AllReduceStrategy::Naive => link.alpha_us + (pf - 1.0) * b / bw,
        AllReduceStrategy::Tree => 2.0 * logp * (link.alpha_us + b / bw),
        AllReduceStrategy::Ring => 2.0 * (pf - 1.0) * link.alpha_us + 2.0 * b * (pf - 1.0) / (pf * bw),
        AllReduceStrategy::Rsag => 2.0 * logp * link.alpha_us + 2.0 * b * (pf - 1.0) / (pf * bw),
    }
}

/// The cheapest strategy for `payload_bytes` among `p` participants on
/// `link` under the alpha-beta model: small payloads are latency-bound
/// (→ `Naive`), large payloads bandwidth-bound (→ `Rsag`), with the
/// crossover shifting down on higher-latency links. Earlier-listed
/// strategies win ties.
pub fn pick_collective_on(p: usize, payload_bytes: u64, link: &LinkCost) -> AllReduceStrategy {
    if p <= 1 {
        return AllReduceStrategy::Naive;
    }
    let mut best = AllReduceStrategy::Naive;
    let mut best_t = collective_time_us(best, p, payload_bytes, link);
    for s in [AllReduceStrategy::Tree, AllReduceStrategy::Ring, AllReduceStrategy::Rsag] {
        let t = collective_time_us(s, p, payload_bytes, link);
        if t < best_t {
            best = s;
            best_t = t;
        }
    }
    best
}

/// Strategy pick for a gradient all-reduce of `payload_bytes` on a
/// fabric shaped by `topo`: flat fabrics reduce among all `P` PEs on
/// the fast class; replicated fabrics are priced by the inter-group
/// phase among the `P/r` group leaders on the slow class (the
/// intra-group hops ride the fast links and are never binding). This is
/// what `--allreduce auto` resolves through, and the pick is logged in
/// the training reports.
pub fn pick_collective(payload_bytes: u64, topo: &Topology, fm: &FabricModel) -> AllReduceStrategy {
    let participants = if topo.replication > 1 { topo.groups() } else { topo.num_pes };
    pick_collective_on(participants, payload_bytes, fm.binding_link(topo))
}

/// Hardware constants for one multi-GPU system (paper Table 4 header).
#[derive(Clone, Debug)]
pub struct SystemPreset {
    pub name: &'static str,
    pub num_pes: usize,
    /// PE memory bandwidth γ, GB/s.
    pub gamma: f64,
    /// inter-PE all-to-all bandwidth α, GB/s.
    pub alpha: f64,
    /// storage (PCI-e) bandwidth β, GB/s.
    pub beta: f64,
}

/// The three systems of Table 4.
pub const PRESETS: &[SystemPreset] = &[
    SystemPreset { name: "4xA100", num_pes: 4, gamma: 2000.0, alpha: 600.0, beta: 64.0 },
    SystemPreset { name: "8xA100", num_pes: 8, gamma: 2000.0, alpha: 600.0, beta: 64.0 },
    SystemPreset { name: "16xV100", num_pes: 16, gamma: 900.0, alpha: 300.0, beta: 32.0 },
];

pub fn preset(name: &str) -> Option<&'static SystemPreset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Per-tier bandwidths of the feature store: the hot tier serves decoded
/// rows out of PE memory (γ), the cold tier pulls encoded rows over the
/// storage link (β). Drives the prefetcher's row budget — how many cold
/// rows can be promoted per batch without the prefetch stream outrunning
/// the link the gather itself needs.
#[derive(Clone, Copy, Debug)]
pub struct TierBandwidths {
    /// hot-tier (PE memory) bandwidth, GB/s.
    pub hot_gbps: f64,
    /// cold-tier (storage) bandwidth, GB/s.
    pub cold_gbps: f64,
}

impl TierBandwidths {
    pub fn of(p: &SystemPreset) -> TierBandwidths {
        TierBandwidths { hot_gbps: p.gamma, cold_gbps: p.beta }
    }
}

/// Slice of the inter-batch gap the prefetcher may occupy on the cold
/// link (µs). Deliberately small: prefetch rides in the sampling stage's
/// shadow, it must not contend with the gather's own β reads.
pub const PREFETCH_WINDOW_US: f64 = 200.0;

/// Rows of `row_bytes` wire bytes the cold tier can deliver inside one
/// prefetch window at `tb.cold_gbps` — the budget the stream hands
/// [`crate::feature::FeatureStore::prefetch_into_hot`].
pub fn prefetch_row_budget(tb: &TierBandwidths, row_bytes: usize, window_us: f64) -> usize {
    if row_bytes == 0 {
        return 0;
    }
    ((window_us * tb.cold_gbps * 1e3) / row_bytes as f64).floor() as usize
}

/// The budget under the default (4xA100) preset and window — what
/// [`crate::pipeline::EngineStream`] uses when no preset is in scope.
/// Smaller rows ⇒ more rows per window: compression widens the prefetch
/// reach by the codec ratio.
pub fn default_prefetch_row_budget(row_bytes: usize) -> usize {
    prefetch_row_budget(&TierBandwidths::of(preset("4xA100").unwrap()), row_bytes, PREFETCH_WINDOW_US)
}

/// Model-cost descriptor: dims + the paper's model-complexity factor `M`
/// (R-GCN runs ~8 relation-typed weight matrices per layer; its F/B is
/// roughly an order of magnitude heavier than GCN's at equal counts —
/// compare Table 4's 8.9 ms vs 199.9 ms rows).
#[derive(Clone, Copy, Debug)]
pub struct ModelCost {
    pub d_in: usize,
    pub hidden: usize,
    pub m_factor: f64,
}

impl ModelCost {
    pub fn gcn(d_in: usize, hidden: usize) -> ModelCost {
        ModelCost { d_in, hidden, m_factor: 1.0 }
    }
    pub fn rgcn(d_in: usize, hidden: usize) -> ModelCost {
        ModelCost { d_in, hidden, m_factor: 8.0 }
    }
}

/// Estimated per-minibatch stage times (ms), mirroring Table 4 columns.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub sampling_ms: f64,
    /// feature copy without cache (all requests hit storage).
    pub feature_ms: f64,
    /// feature copy through the LRU cache (κ=1 miss rate).
    pub feature_cache_ms: f64,
    pub fb_ms: f64,
}

impl StageTimes {
    /// Total per the paper's rule: fastest feature path + sampling + F/B.
    pub fn total_ms(&self) -> f64 {
        self.sampling_ms + self.feature_cache_ms.min(self.feature_ms) + self.fb_ms
    }
}

const MS: f64 = 1e3;
const GB: f64 = 1e9;

/// Estimate stage times from measured engine counts.
///
/// `report` must come from an engine run with `num_pes == preset.num_pes`
/// (counts are per-PE maxima). `d_feat` is the dataset's embedding dim.
pub fn estimate(
    report: &EngineReport,
    preset: &SystemPreset,
    model: &ModelCost,
    d_feat: usize,
) -> StageTimes {
    let is_coop = report.mode == "Coop";
    let layers = report.e.len();
    // the Table-1 estimate stays a *counts* model in decoded f32 units
    // (the paper's formulas know no codec); measured wire bytes live in
    // the engine report's byte ledgers instead
    let fbytes = 4.0;

    // --- Sampling: adjacency traffic at β + id redistribution at α ----
    let mut samp_bytes_beta = 0.0;
    let mut samp_bytes_alpha = 0.0;
    for l in 0..layers {
        // reading neighbor lists: 8 B per candidate edge examined (the
        // samplers examine the full neighbor list of every dst), plus
        // 16 B bookkeeping per processed vertex
        samp_bytes_beta += report.e[l] * 8.0 * 4.0 + report.s[l] * 16.0;
        if is_coop {
            samp_bytes_alpha += report.cross[l] * 4.0 * 2.0; // ids out + back
        }
    }
    let sampling_ms = (samp_bytes_beta / (preset.beta * GB)
        + samp_bytes_alpha / (preset.alpha * GB))
        * MS;

    // --- Feature loading -----------------------------------------------
    let row = d_feat as f64 * fbytes;
    let fabric = if is_coop { report.feat_fabric_rows * row / (preset.alpha * GB) } else { 0.0 };
    let feature_ms = (report.feat_requested * row / (preset.beta * GB) + fabric) * MS;
    let feature_cache_ms = (report.feat_misses * row / (preset.beta * GB) + fabric) * MS;

    // --- Forward/backward ----------------------------------------------
    // memory-bound estimate: each layer reads its source rows, streams
    // edge messages, writes dst rows; backward roughly doubles traffic
    // (x3 total). Hidden dim everywhere except the deepest layer's input.
    let mut fb_bytes_gamma = 0.0;
    let mut fb_bytes_alpha = 0.0;
    for l in 0..layers {
        let d_src = if l == layers - 1 { model.d_in as f64 } else { model.hidden as f64 };
        let d_dst = model.hidden as f64;
        let src_rows = if l == layers - 1 {
            report.s[layers]
        } else {
            report.tilde.get(l).copied().unwrap_or(report.s[l + 1]).max(report.s[l + 1])
        };
        fb_bytes_gamma += (report.e[l] * d_src          // edge gathers
            + src_rows * d_src                           // source reads
            + report.s[l] * (d_src + d_dst))             // agg + transform
            * fbytes
            * 3.0; // fwd + bwd traffic
        if is_coop {
            // activation redistribution fwd + gradient redistribution bwd
            fb_bytes_alpha += report.cross[l] * d_src * fbytes * 2.0;
        }
    }
    let fb_ms = (model.m_factor * fb_bytes_gamma / (preset.gamma * GB)
        + fb_bytes_alpha / (preset.alpha * GB))
        * MS;

    StageTimes { sampling_ms, feature_ms, feature_cache_ms, fb_ms }
}

/// Feature-cache time for an alternative miss count (the `Cache, κ`
/// column: same run shape, κ=256 miss rate).
pub fn feature_cache_ms_for(
    report: &EngineReport,
    preset: &SystemPreset,
    d_feat: usize,
    misses: f64,
    fabric_rows: f64,
) -> f64 {
    let row = d_feat as f64 * 4.0;
    let fabric = if report.mode == "Coop" { fabric_rows * row / (preset.alpha * GB) } else { 0.0 };
    (misses * row / (preset.beta * GB) + fabric) * MS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report(mode: &str, scale: f64) -> EngineReport {
        EngineReport {
            mode: mode.to_string(),
            num_pes: 4,
            s: vec![1024.0, 10_000.0 * scale, 60_000.0 * scale, 150_000.0 * scale],
            e: vec![10_000.0 * scale, 90_000.0 * scale, 500_000.0 * scale],
            tilde: vec![12_000.0 * scale, 100_000.0 * scale, 550_000.0 * scale],
            cross: if mode == "Coop" {
                vec![9_000.0 * scale, 75_000.0 * scale, 400_000.0 * scale]
            } else {
                vec![0.0; 3]
            },
            feat_requested: 150_000.0 * scale,
            feat_misses: 90_000.0 * scale,
            feat_fabric_rows: if mode == "Coop" { 110_000.0 * scale } else { 0.0 },
            cache_miss_rate: 0.6,
            dup_factor: 1.4,
            ..Default::default()
        }
    }

    #[test]
    fn cache_beats_no_cache() {
        let r = fake_report("Indep", 1.0);
        let t = estimate(&r, preset("4xA100").unwrap(), &ModelCost::gcn(128, 256), 128);
        assert!(t.feature_cache_ms < t.feature_ms);
        assert!(t.total_ms() > 0.0);
    }

    #[test]
    fn coop_with_smaller_counts_wins_total() {
        // coop processes ~40% fewer vertices (the concavity effect): its
        // total must win despite paying α traffic.
        let ri = fake_report("Indep", 1.0);
        let rc = fake_report("Coop", 0.65);
        let p = preset("4xA100").unwrap();
        let m = ModelCost::gcn(128, 256);
        let ti = estimate(&ri, p, &m, 128);
        let tc = estimate(&rc, p, &m, 128);
        assert!(tc.total_ms() < ti.total_ms(), "coop {tc:?} vs indep {ti:?}");
    }

    #[test]
    fn rgcn_fb_heavier_than_gcn() {
        let r = fake_report("Indep", 1.0);
        let p = preset("4xA100").unwrap();
        let g = estimate(&r, p, &ModelCost::gcn(128, 256), 128);
        let rg = estimate(&r, p, &ModelCost::rgcn(128, 256), 128);
        assert!(rg.fb_ms > 5.0 * g.fb_ms);
        assert_eq!(rg.sampling_ms, g.sampling_ms, "M only affects F/B");
    }

    #[test]
    fn slower_system_slower_everything() {
        let r = fake_report("Coop", 1.0);
        let m = ModelCost::gcn(128, 256);
        let fast = estimate(&r, preset("4xA100").unwrap(), &m, 128);
        let slow = estimate(&r, preset("16xV100").unwrap(), &m, 128);
        assert!(slow.sampling_ms > fast.sampling_ms);
        assert!(slow.fb_ms > fast.fb_ms);
        assert!(slow.feature_cache_ms > fast.feature_cache_ms);
    }

    #[test]
    fn presets_match_paper_header() {
        let a = preset("4xA100").unwrap();
        assert_eq!((a.gamma, a.alpha, a.beta), (2000.0, 600.0, 64.0));
        let v = preset("16xV100").unwrap();
        assert_eq!((v.gamma, v.alpha, v.beta), (900.0, 300.0, 32.0));
        assert!(preset("nope").is_none());
    }

    #[test]
    fn pick_collective_spans_payloads_and_link_classes() {
        let fm = FabricModel::default();
        let flat = Topology::flat(8);
        let repl = Topology::new(16, 2); // 8 leaders over the slow class
        // small payloads are latency-bound: Naive on both link classes
        assert_eq!(pick_collective(4 * 1024, &flat, &fm), AllReduceStrategy::Naive);
        assert_eq!(pick_collective(4 * 1024, &repl, &fm), AllReduceStrategy::Naive);
        // large payloads are bandwidth-bound: Rsag on both link classes
        assert_eq!(pick_collective(64 << 20, &flat, &fm), AllReduceStrategy::Rsag);
        assert_eq!(pick_collective(64 << 20, &repl, &fm), AllReduceStrategy::Rsag);
        // the slow class pays 5x the startup latency, so its crossover
        // sits lower: a ~1 MB payload is still latency-bound on intra
        // links but already bandwidth-bound on inter links
        assert_eq!(pick_collective_on(8, 1_000_000, &fm.intra), AllReduceStrategy::Naive);
        assert_eq!(pick_collective_on(8, 1_000_000, &fm.inter), AllReduceStrategy::Rsag);
        // degenerate fabrics have nothing to pick
        assert_eq!(pick_collective_on(1, 1 << 30, &fm.inter), AllReduceStrategy::Naive);
        // bandwidth overrides move the crossover: starving the intra
        // class makes even the ~1 MB payload bandwidth-bound there
        let slow = FabricModel::with_bandwidths(Some(10.0), None);
        assert_eq!(pick_collective_on(8, 1_000_000, &slow.intra), AllReduceStrategy::Rsag);
    }

    #[test]
    fn prefetch_budget_tracks_cold_bandwidth_and_codec_width() {
        let tb = TierBandwidths::of(preset("4xA100").unwrap());
        assert!(tb.hot_gbps > tb.cold_gbps);
        // 200us at 64 GB/s cold bandwidth moves 12.8 MB; f32 rows of dim 16
        // are 64 wire bytes, int8 rows are 21, so the narrower codec fits
        // strictly more rows into the same window.
        let f32_rows = prefetch_row_budget(&tb, 64, PREFETCH_WINDOW_US);
        let int8_rows = prefetch_row_budget(&tb, 21, PREFETCH_WINDOW_US);
        assert_eq!(f32_rows, 200_000);
        assert!(int8_rows as f64 >= 3.0 * f32_rows as f64);
        assert_eq!(prefetch_row_budget(&tb, 0, PREFETCH_WINDOW_US), 0);
        assert_eq!(default_prefetch_row_budget(64), f32_rows);
    }
}
