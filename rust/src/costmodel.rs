//! Bandwidth cost model — the paper's Table 1 complexity formulas with
//! the Table 4 hardware constants.
//!
//! The paper's runtime claims are driven by *counts* (per-PE sampled
//! vertices/edges, fabric traffic, cache misses) passed through three
//! bandwidths: γ (PE memory), α (inter-PE fabric / NVLink), β (storage /
//! PCI-e). We measure the identical counts with the simulation engine
//! ([`crate::coop::engine`]) on the synthetic dataset twins and estimate
//! per-stage times on the paper's three systems. Absolute milliseconds
//! are not expected to match the paper (different graphs, scaled sizes);
//! the *shape* — who wins, how the gap grows with P — is the
//! reproduction target (see EXPERIMENTS.md).
//!
//! | Stage            | Independent                      | Cooperative                                   |
//! |------------------|----------------------------------|-----------------------------------------------|
//! | Sampling         | O(|S^l(B/P)| / β)                | O(|S_p^l(B)|/β + |S̃_p^{l+1}(B)|·c/α)          |
//! | Feature loading  | O(|S^L(B/P)|·dρ/β)               | O(|S_p^L(B)|·dρ/β + |S̃_p^L(B)|·dc/α)          |
//! | Forward/Backward | O(M(S,E,S')·d/γ)                 | O(M(S_p,E_p,S̃_p)·d/γ + |S̃_p^{l+1}|·dc̃/α)     |

use crate::coop::engine::EngineReport;

/// Hardware constants for one multi-GPU system (paper Table 4 header).
#[derive(Clone, Debug)]
pub struct SystemPreset {
    pub name: &'static str,
    pub num_pes: usize,
    /// PE memory bandwidth γ, GB/s.
    pub gamma: f64,
    /// inter-PE all-to-all bandwidth α, GB/s.
    pub alpha: f64,
    /// storage (PCI-e) bandwidth β, GB/s.
    pub beta: f64,
}

/// The three systems of Table 4.
pub const PRESETS: &[SystemPreset] = &[
    SystemPreset { name: "4xA100", num_pes: 4, gamma: 2000.0, alpha: 600.0, beta: 64.0 },
    SystemPreset { name: "8xA100", num_pes: 8, gamma: 2000.0, alpha: 600.0, beta: 64.0 },
    SystemPreset { name: "16xV100", num_pes: 16, gamma: 900.0, alpha: 300.0, beta: 32.0 },
];

pub fn preset(name: &str) -> Option<&'static SystemPreset> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Per-tier bandwidths of the feature store: the hot tier serves decoded
/// rows out of PE memory (γ), the cold tier pulls encoded rows over the
/// storage link (β). Drives the prefetcher's row budget — how many cold
/// rows can be promoted per batch without the prefetch stream outrunning
/// the link the gather itself needs.
#[derive(Clone, Copy, Debug)]
pub struct TierBandwidths {
    /// hot-tier (PE memory) bandwidth, GB/s.
    pub hot_gbps: f64,
    /// cold-tier (storage) bandwidth, GB/s.
    pub cold_gbps: f64,
}

impl TierBandwidths {
    pub fn of(p: &SystemPreset) -> TierBandwidths {
        TierBandwidths { hot_gbps: p.gamma, cold_gbps: p.beta }
    }
}

/// Slice of the inter-batch gap the prefetcher may occupy on the cold
/// link (µs). Deliberately small: prefetch rides in the sampling stage's
/// shadow, it must not contend with the gather's own β reads.
pub const PREFETCH_WINDOW_US: f64 = 200.0;

/// Rows of `row_bytes` wire bytes the cold tier can deliver inside one
/// prefetch window at `tb.cold_gbps` — the budget the stream hands
/// [`crate::feature::FeatureStore::prefetch_into_hot`].
pub fn prefetch_row_budget(tb: &TierBandwidths, row_bytes: usize, window_us: f64) -> usize {
    if row_bytes == 0 {
        return 0;
    }
    ((window_us * tb.cold_gbps * 1e3) / row_bytes as f64).floor() as usize
}

/// The budget under the default (4xA100) preset and window — what
/// [`crate::pipeline::EngineStream`] uses when no preset is in scope.
/// Smaller rows ⇒ more rows per window: compression widens the prefetch
/// reach by the codec ratio.
pub fn default_prefetch_row_budget(row_bytes: usize) -> usize {
    prefetch_row_budget(&TierBandwidths::of(preset("4xA100").unwrap()), row_bytes, PREFETCH_WINDOW_US)
}

/// Model-cost descriptor: dims + the paper's model-complexity factor `M`
/// (R-GCN runs ~8 relation-typed weight matrices per layer; its F/B is
/// roughly an order of magnitude heavier than GCN's at equal counts —
/// compare Table 4's 8.9 ms vs 199.9 ms rows).
#[derive(Clone, Copy, Debug)]
pub struct ModelCost {
    pub d_in: usize,
    pub hidden: usize,
    pub m_factor: f64,
}

impl ModelCost {
    pub fn gcn(d_in: usize, hidden: usize) -> ModelCost {
        ModelCost { d_in, hidden, m_factor: 1.0 }
    }
    pub fn rgcn(d_in: usize, hidden: usize) -> ModelCost {
        ModelCost { d_in, hidden, m_factor: 8.0 }
    }
}

/// Estimated per-minibatch stage times (ms), mirroring Table 4 columns.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub sampling_ms: f64,
    /// feature copy without cache (all requests hit storage).
    pub feature_ms: f64,
    /// feature copy through the LRU cache (κ=1 miss rate).
    pub feature_cache_ms: f64,
    pub fb_ms: f64,
}

impl StageTimes {
    /// Total per the paper's rule: fastest feature path + sampling + F/B.
    pub fn total_ms(&self) -> f64 {
        self.sampling_ms + self.feature_cache_ms.min(self.feature_ms) + self.fb_ms
    }
}

const MS: f64 = 1e3;
const GB: f64 = 1e9;

/// Estimate stage times from measured engine counts.
///
/// `report` must come from an engine run with `num_pes == preset.num_pes`
/// (counts are per-PE maxima). `d_feat` is the dataset's embedding dim.
pub fn estimate(
    report: &EngineReport,
    preset: &SystemPreset,
    model: &ModelCost,
    d_feat: usize,
) -> StageTimes {
    let is_coop = report.mode == "Coop";
    let layers = report.e.len();
    // the Table-1 estimate stays a *counts* model in decoded f32 units
    // (the paper's formulas know no codec); measured wire bytes live in
    // the engine report's byte ledgers instead
    let fbytes = 4.0;

    // --- Sampling: adjacency traffic at β + id redistribution at α ----
    let mut samp_bytes_beta = 0.0;
    let mut samp_bytes_alpha = 0.0;
    for l in 0..layers {
        // reading neighbor lists: 8 B per candidate edge examined (the
        // samplers examine the full neighbor list of every dst), plus
        // 16 B bookkeeping per processed vertex
        samp_bytes_beta += report.e[l] * 8.0 * 4.0 + report.s[l] * 16.0;
        if is_coop {
            samp_bytes_alpha += report.cross[l] * 4.0 * 2.0; // ids out + back
        }
    }
    let sampling_ms = (samp_bytes_beta / (preset.beta * GB)
        + samp_bytes_alpha / (preset.alpha * GB))
        * MS;

    // --- Feature loading -----------------------------------------------
    let row = d_feat as f64 * fbytes;
    let fabric = if is_coop { report.feat_fabric_rows * row / (preset.alpha * GB) } else { 0.0 };
    let feature_ms = (report.feat_requested * row / (preset.beta * GB) + fabric) * MS;
    let feature_cache_ms = (report.feat_misses * row / (preset.beta * GB) + fabric) * MS;

    // --- Forward/backward ----------------------------------------------
    // memory-bound estimate: each layer reads its source rows, streams
    // edge messages, writes dst rows; backward roughly doubles traffic
    // (x3 total). Hidden dim everywhere except the deepest layer's input.
    let mut fb_bytes_gamma = 0.0;
    let mut fb_bytes_alpha = 0.0;
    for l in 0..layers {
        let d_src = if l == layers - 1 { model.d_in as f64 } else { model.hidden as f64 };
        let d_dst = model.hidden as f64;
        let src_rows = if l == layers - 1 {
            report.s[layers]
        } else {
            report.tilde.get(l).copied().unwrap_or(report.s[l + 1]).max(report.s[l + 1])
        };
        fb_bytes_gamma += (report.e[l] * d_src          // edge gathers
            + src_rows * d_src                           // source reads
            + report.s[l] * (d_src + d_dst))             // agg + transform
            * fbytes
            * 3.0; // fwd + bwd traffic
        if is_coop {
            // activation redistribution fwd + gradient redistribution bwd
            fb_bytes_alpha += report.cross[l] * d_src * fbytes * 2.0;
        }
    }
    let fb_ms = (model.m_factor * fb_bytes_gamma / (preset.gamma * GB)
        + fb_bytes_alpha / (preset.alpha * GB))
        * MS;

    StageTimes { sampling_ms, feature_ms, feature_cache_ms, fb_ms }
}

/// Feature-cache time for an alternative miss count (the `Cache, κ`
/// column: same run shape, κ=256 miss rate).
pub fn feature_cache_ms_for(
    report: &EngineReport,
    preset: &SystemPreset,
    d_feat: usize,
    misses: f64,
    fabric_rows: f64,
) -> f64 {
    let row = d_feat as f64 * 4.0;
    let fabric = if report.mode == "Coop" { fabric_rows * row / (preset.alpha * GB) } else { 0.0 };
    (misses * row / (preset.beta * GB) + fabric) * MS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_report(mode: &str, scale: f64) -> EngineReport {
        EngineReport {
            mode: mode.to_string(),
            num_pes: 4,
            s: vec![1024.0, 10_000.0 * scale, 60_000.0 * scale, 150_000.0 * scale],
            e: vec![10_000.0 * scale, 90_000.0 * scale, 500_000.0 * scale],
            tilde: vec![12_000.0 * scale, 100_000.0 * scale, 550_000.0 * scale],
            cross: if mode == "Coop" {
                vec![9_000.0 * scale, 75_000.0 * scale, 400_000.0 * scale]
            } else {
                vec![0.0; 3]
            },
            feat_requested: 150_000.0 * scale,
            feat_misses: 90_000.0 * scale,
            feat_fabric_rows: if mode == "Coop" { 110_000.0 * scale } else { 0.0 },
            cache_miss_rate: 0.6,
            dup_factor: 1.4,
            ..Default::default()
        }
    }

    #[test]
    fn cache_beats_no_cache() {
        let r = fake_report("Indep", 1.0);
        let t = estimate(&r, preset("4xA100").unwrap(), &ModelCost::gcn(128, 256), 128);
        assert!(t.feature_cache_ms < t.feature_ms);
        assert!(t.total_ms() > 0.0);
    }

    #[test]
    fn coop_with_smaller_counts_wins_total() {
        // coop processes ~40% fewer vertices (the concavity effect): its
        // total must win despite paying α traffic.
        let ri = fake_report("Indep", 1.0);
        let rc = fake_report("Coop", 0.65);
        let p = preset("4xA100").unwrap();
        let m = ModelCost::gcn(128, 256);
        let ti = estimate(&ri, p, &m, 128);
        let tc = estimate(&rc, p, &m, 128);
        assert!(tc.total_ms() < ti.total_ms(), "coop {tc:?} vs indep {ti:?}");
    }

    #[test]
    fn rgcn_fb_heavier_than_gcn() {
        let r = fake_report("Indep", 1.0);
        let p = preset("4xA100").unwrap();
        let g = estimate(&r, p, &ModelCost::gcn(128, 256), 128);
        let rg = estimate(&r, p, &ModelCost::rgcn(128, 256), 128);
        assert!(rg.fb_ms > 5.0 * g.fb_ms);
        assert_eq!(rg.sampling_ms, g.sampling_ms, "M only affects F/B");
    }

    #[test]
    fn slower_system_slower_everything() {
        let r = fake_report("Coop", 1.0);
        let m = ModelCost::gcn(128, 256);
        let fast = estimate(&r, preset("4xA100").unwrap(), &m, 128);
        let slow = estimate(&r, preset("16xV100").unwrap(), &m, 128);
        assert!(slow.sampling_ms > fast.sampling_ms);
        assert!(slow.fb_ms > fast.fb_ms);
        assert!(slow.feature_cache_ms > fast.feature_cache_ms);
    }

    #[test]
    fn presets_match_paper_header() {
        let a = preset("4xA100").unwrap();
        assert_eq!((a.gamma, a.alpha, a.beta), (2000.0, 600.0, 64.0));
        let v = preset("16xV100").unwrap();
        assert_eq!((v.gamma, v.alpha, v.beta), (900.0, 300.0, 32.0));
        assert!(preset("nope").is_none());
    }

    #[test]
    fn prefetch_budget_tracks_cold_bandwidth_and_codec_width() {
        let tb = TierBandwidths::of(preset("4xA100").unwrap());
        assert!(tb.hot_gbps > tb.cold_gbps);
        // 200us at 64 GB/s cold bandwidth moves 12.8 MB; f32 rows of dim 16
        // are 64 wire bytes, int8 rows are 21, so the narrower codec fits
        // strictly more rows into the same window.
        let f32_rows = prefetch_row_budget(&tb, 64, PREFETCH_WINDOW_US);
        let int8_rows = prefetch_row_budget(&tb, 21, PREFETCH_WINDOW_US);
        assert_eq!(f32_rows, 200_000);
        assert!(int8_rows as f64 >= 3.0 * f32_rows as f64);
        assert_eq!(prefetch_row_budget(&tb, 0, PREFETCH_WINDOW_US), 0);
        assert_eq!(default_prefetch_row_budget(64), f32_rows);
    }
}
