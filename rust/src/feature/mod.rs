//! The feature plane's storage tier.
//!
//! The paper's bandwidth model distinguishes *storage* reads (β, a cache
//! miss pulls a row out of vertex-embedding storage) from *fabric*
//! transfers (α, cooperative loading redistributes rows between PEs).
//! This module is the storage side:
//!
//! - [`store`] — the [`FeatureStore`] read seam and
//!   [`PartitionedFeatureStore`], the in-memory one-shard-per-PE f32
//!   implementation built from [`crate::graph::Dataset::write_features`]
//!   at pipeline build time.
//! - [`codec`] — pluggable row codecs ([`Codec::F32`] passthrough,
//!   [`Codec::Fp16`], [`Codec::Int8`] with per-row scale/zero-point):
//!   encode once at store build, decode on gather, exact encoded
//!   [`Codec::row_bytes`] so every byte ledger reports wire bytes.
//! - [`tiered`] — [`TieredStore`], a capacity-bounded hot tier of
//!   decoded rows (plus a prefetch annex) over compressed cold shards,
//!   classified per row by [`Tier`].
//!
//! The caches ([`crate::coop::cache`]), the loader
//! ([`crate::coop::feature_loader`]), and the training streams
//! ([`crate::pipeline::TrainStream`]) all read rows through the trait,
//! so the byte accounting in [`crate::coop::engine::EngineReport`] is
//! derived from real movement — at whatever wire size the codec yields.

pub mod codec;
pub mod store;
pub mod tiered;

pub use codec::Codec;
pub use store::{FeatureStore, PartitionedFeatureStore, Tier};
pub use tiered::TieredStore;
