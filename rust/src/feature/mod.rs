//! The feature plane's storage tier.
//!
//! The paper's bandwidth model distinguishes *storage* reads (β, a cache
//! miss pulls a row out of vertex-embedding storage) from *fabric*
//! transfers (α, cooperative loading redistributes rows between PEs).
//! This module is the storage side: [`FeatureStore`] is the read seam,
//! [`PartitionedFeatureStore`] the in-memory one-shard-per-PE
//! implementation built from [`crate::graph::Dataset::write_features`]
//! at pipeline build time. The caches ([`crate::coop::cache`]), the
//! loader ([`crate::coop::feature_loader`]), and the training streams
//! ([`crate::pipeline::TrainStream`]) all read rows through it, so the
//! byte accounting in [`crate::coop::engine::EngineReport`] is derived
//! from real movement.

pub mod store;

pub use store::{FeatureStore, PartitionedFeatureStore};
