//! The vertex-embedding storage tier (the β-bandwidth side of the
//! paper's Table 1).
//!
//! [`FeatureStore`] is the one seam the feature plane reads rows
//! through: LRU caches fill their arenas from it on a miss, training
//! streams gather dense input buffers from it, and every byte copied
//! out of it is a byte "from storage" in the bandwidth accounting.
//!
//! [`PartitionedFeatureStore`] is the in-memory implementation: one
//! shard per PE holding its owned vertices' f32 rows row-major,
//! materialized from [`Dataset::write_features`] once at pipeline build
//! time. A [`PartitionedFeatureStore::single_shard`] constructor covers
//! the 1-PE / training case (the whole matrix in shard 0).

use super::codec::Codec;
use crate::graph::{Dataset, Partition, VertexId};

/// Which storage tier a row is served from — decides which bandwidth
/// lane (γ for [`Tier::Hot`] PE memory, β for [`Tier::Cold`] storage)
/// its bytes are charged to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Decoded row resident in PE memory (static hot set or prefetch
    /// annex).
    Hot,
    /// Encoded row in compressed base storage.
    Cold,
}

/// Read access to vertex feature rows. Object-safe; implementations must
/// be shareable across PE threads (`Send + Sync`) since every PE reads
/// its own shard concurrently.
pub trait FeatureStore: Send + Sync {
    /// Feature dimensionality (floats per row).
    fn dim(&self) -> usize;

    /// How rows are encoded at rest and on the wire.
    fn codec(&self) -> Codec {
        Codec::F32
    }

    /// Encoded bytes of one row — the wire size every byte ledger
    /// charges per row pulled from storage or shipped over the fabric.
    fn row_bytes(&self) -> usize {
        self.codec().row_bytes(self.dim())
    }

    /// Which tier serves `v` right now (all-cold unless the store
    /// tiers).
    fn tier_of(&self, _v: VertexId) -> Tier {
        Tier::Cold
    }

    /// Copy the decoded row of `v` into `out` (`out.len() == dim()`).
    fn copy_row(&self, v: VertexId, out: &mut [f32]);

    /// Append the *encoded* row of `v` (exactly [`row_bytes`] bytes,
    /// after a clear) — what the fabric ships so cross-PE traffic moves
    /// wire bytes, not decoded f32. The default round-trips through
    /// `copy_row` + encode; stores holding encoded rows should override
    /// with a direct byte copy (re-quantizing a decoded row drifts).
    ///
    /// [`row_bytes`]: FeatureStore::row_bytes
    fn copy_encoded_row(&self, v: VertexId, out: &mut Vec<u8>) {
        let mut row = vec![0f32; self.dim()];
        self.copy_row(v, &mut row);
        out.clear();
        self.codec().encode_row(&row, out);
    }

    /// Promote up to `budget_rows` of `vs` into the hot tier ahead of
    /// the next gather; returns rows actually fetched from cold
    /// storage. No-op (returns 0) for untiered stores.
    fn prefetch_into_hot(&self, _vs: &[VertexId], _budget_rows: usize) -> u64 {
        0
    }

    /// Batched gather into a dense row-major buffer (replaces the old
    /// `Dataset::gather_features` hash-regeneration path on every
    /// consumer).
    fn gather(&self, vs: &[VertexId], out: &mut Vec<f32>) {
        let d = self.dim();
        out.clear();
        out.resize(vs.len() * d, 0.0);
        self.gather_into(vs, out);
    }

    /// Gather into a preallocated slice (`out.len() == vs.len() * dim()`)
    /// — used by the trainer to fill the prefix of its padded buffer
    /// without an intermediate copy.
    fn gather_into(&self, vs: &[VertexId], out: &mut [f32]) {
        let d = self.dim();
        assert_eq!(out.len(), vs.len() * d, "gather_into buffer shape");
        for (i, &v) in vs.iter().enumerate() {
            self.copy_row(v, &mut out[i * d..(i + 1) * d]);
        }
    }
}

/// In-memory partitioned feature storage: shard `p` holds the rows of
/// the vertices PE `p` owns, row-major in owner-local row order.
///
/// Lookup is O(1): a per-vertex `(shard, row)` index built at
/// construction. Rows are materialized once from
/// [`Dataset::write_features`]; after that, the dataset's hash generator
/// is off the feature path entirely — all bytes come from here.
pub struct PartitionedFeatureStore {
    dim: usize,
    shards: Vec<Vec<f32>>,
    shard_of: Vec<u32>,
    row_of: Vec<u32>,
}

impl PartitionedFeatureStore {
    /// Materialize one shard per PE from `part` (the pipeline-build-time
    /// constructor).
    pub fn build(ds: &Dataset, part: &Partition) -> PartitionedFeatureStore {
        let n = ds.graph.num_vertices();
        let d = ds.feat_dim;
        let p = part.num_parts;
        let mut shard_of = vec![0u32; n];
        let mut row_of = vec![0u32; n];
        let mut counts = vec![0usize; p];
        for v in 0..n {
            let s = part.part_of(v as VertexId);
            shard_of[v] = s as u32;
            row_of[v] = counts[s] as u32;
            counts[s] += 1;
        }
        let mut shards: Vec<Vec<f32>> = counts.iter().map(|&c| vec![0.0; c * d]).collect();
        for v in 0..n {
            let s = shard_of[v] as usize;
            let r = row_of[v] as usize;
            ds.write_features(v as VertexId, &mut shards[s][r * d..(r + 1) * d]);
        }
        PartitionedFeatureStore { dim: d, shards, shard_of, row_of }
    }

    /// The whole feature matrix in one shard — the training-stream /
    /// single-PE layout.
    pub fn single_shard(ds: &Dataset) -> PartitionedFeatureStore {
        let n = ds.graph.num_vertices();
        let d = ds.feat_dim;
        let mut shard = vec![0.0f32; n * d];
        for v in 0..n {
            ds.write_features(v as VertexId, &mut shard[v * d..(v + 1) * d]);
        }
        PartitionedFeatureStore {
            dim: d,
            shards: vec![shard],
            shard_of: vec![0; n],
            row_of: (0..n as u32).collect(),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard (owning PE) holds `v`'s row.
    pub fn shard_of(&self, v: VertexId) -> usize {
        self.shard_of[v as usize] as usize
    }

    /// Rows held by shard `p`.
    pub fn shard_rows(&self, p: usize) -> usize {
        self.shards[p].len() / self.dim.max(1)
    }

    /// Total resident bytes across all shards.
    pub fn total_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.len() * 4).sum()
    }

    /// Borrow the stored row of `v` (concrete-type fast path; the trait
    /// seam goes through [`FeatureStore::copy_row`] so encoded stores
    /// can decode on the way out).
    pub fn row(&self, v: VertexId) -> &[f32] {
        let s = self.shard_of[v as usize] as usize;
        let r = self.row_of[v as usize] as usize;
        &self.shards[s][r * self.dim..(r + 1) * self.dim]
    }
}

impl FeatureStore for PartitionedFeatureStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn copy_row(&self, v: VertexId, out: &mut [f32]) {
        out.copy_from_slice(self.row(v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{datasets, partition};

    #[test]
    fn partitioned_rows_match_dataset_hash_truth() {
        let ds = datasets::build("tiny", 3).unwrap();
        let part = partition::random(&ds.graph, 4, 5);
        let store = PartitionedFeatureStore::build(&ds, &part);
        assert_eq!(store.dim(), ds.feat_dim);
        assert_eq!(store.num_shards(), 4);
        let mut want = vec![0f32; ds.feat_dim];
        for v in [0u32, 7, 999, 1999] {
            ds.write_features(v, &mut want);
            assert_eq!(store.row(v), &want[..], "vertex {v}");
            assert_eq!(store.shard_of(v), part.part_of(v));
        }
    }

    #[test]
    fn shard_sizes_cover_the_partition() {
        let ds = datasets::build("tiny", 1).unwrap();
        let part = partition::random(&ds.graph, 3, 2);
        let store = PartitionedFeatureStore::build(&ds, &part);
        let sizes = part.part_sizes();
        for p in 0..3 {
            assert_eq!(store.shard_rows(p), sizes[p], "shard {p}");
        }
        assert_eq!(store.total_bytes(), ds.graph.num_vertices() * ds.row_bytes());
    }

    #[test]
    fn single_shard_matches_partitioned() {
        let ds = datasets::build("tiny", 2).unwrap();
        let part = partition::random(&ds.graph, 2, 9);
        let a = PartitionedFeatureStore::single_shard(&ds);
        let b = PartitionedFeatureStore::build(&ds, &part);
        for v in (0..ds.graph.num_vertices() as u32).step_by(97) {
            assert_eq!(a.row(v), b.row(v), "vertex {v}");
        }
        assert_eq!(a.num_shards(), 1);
    }

    #[test]
    fn default_trait_surface_is_f32_cold() {
        let ds = datasets::build("tiny", 6).unwrap();
        let store = PartitionedFeatureStore::single_shard(&ds);
        assert_eq!(store.codec(), Codec::F32);
        assert_eq!(store.row_bytes(), store.dim() * 4);
        assert_eq!(store.tier_of(42), Tier::Cold);
        assert_eq!(store.prefetch_into_hot(&[1, 2, 3], 8), 0);
        // default copy_encoded_row == the row's little-endian f32 bytes
        let mut enc = vec![0xAAu8; 3]; // must be cleared first
        store.copy_encoded_row(9, &mut enc);
        assert_eq!(enc.len(), store.row_bytes());
        let want: Vec<u8> = store.row(9).iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(enc, want);
    }

    #[test]
    fn gather_layouts_agree() {
        let ds = datasets::build("tiny", 4).unwrap();
        let store = PartitionedFeatureStore::single_shard(&ds);
        let vs = [5u32, 3, 3, 1900];
        let mut dense = Vec::new();
        store.gather(&vs, &mut dense);
        assert_eq!(dense.len(), vs.len() * store.dim());
        let mut fixed = vec![0f32; vs.len() * store.dim()];
        store.gather_into(&vs, &mut fixed);
        assert_eq!(dense, fixed);
        let d = store.dim();
        for (i, &v) in vs.iter().enumerate() {
            assert_eq!(&dense[i * d..(i + 1) * d], store.row(v), "row {i}");
        }
    }
}
