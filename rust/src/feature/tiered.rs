//! Two-tier compressed feature store: a capacity-bounded **hot tier** of
//! decoded f32 rows over a codec-compressed **cold tier** of encoded
//! shards.
//!
//! The cold tier is the wire truth: every row is encoded once at build
//! ([`Codec::encode_row`]) into per-PE shards, and a cold fill charges
//! the exact encoded [`Codec::row_bytes`] to the storage ledger (β). The
//! hot tier holds *decoded* copies of the hottest vertices — decoded
//! **from the encoded bytes**, so both tiers serve bit-identical values
//! — and a hot fill charges decoded bytes at PE-memory bandwidth (γ)
//! instead. Hot membership is static top-K by degree (the stand-in for
//! observed access frequency: degree is exactly what makes a vertex
//! reappear across sampled neighborhoods and serve's 80/5 hot-set mix),
//! sized by the CLI `--hot-mb` budget at `dim × 4` decoded bytes per
//! row, plus a small FIFO **annex** the costmodel-driven prefetcher
//! ([`FeatureStore::prefetch_into_hot`]) fills with predicted next-batch
//! seed rows.
//!
//! Determinism: the annex mutates only between batches (at the stream's
//! serial seed-drawing point), never during one, so per-batch tier
//! classification is stable across serial/threaded execution; hot and
//! cold serve identical values, so tiering moves bytes between ledgers
//! without changing any count, feature payload, or prediction.

use super::codec::Codec;
use super::store::{FeatureStore, Tier};
use crate::graph::{Dataset, Partition, VertexId};
use std::collections::HashMap;
use std::sync::Mutex;

const NOT_HOT: u32 = u32::MAX;

/// Prefetch annex: a FIFO ring of decoded rows the prefetcher promoted
/// ahead of the next gather. Mutated only between batches.
struct Annex {
    cap: usize,
    map: HashMap<VertexId, usize>,
    /// `cap × dim` decoded rows, slot-major.
    slots: Vec<f32>,
    /// slot → vertex currently occupying it (`NOT_HOT` when empty).
    owner: Vec<VertexId>,
    cursor: usize,
}

/// Codec-compressed cold shards + decoded hot tier behind the
/// [`FeatureStore`] trait.
pub struct TieredStore {
    dim: usize,
    codec: Codec,
    row_bytes: usize,
    shard_of: Vec<u32>,
    row_of: Vec<u32>,
    /// encoded rows, `row_bytes` each, per PE shard.
    shards: Vec<Vec<u8>>,
    /// vertex → static hot-tier row index (`NOT_HOT` when cold).
    hot_pos: Vec<u32>,
    /// decoded rows of the static hot set, row-major.
    hot_rows: Vec<f32>,
    annex: Mutex<Annex>,
}

impl TieredStore {
    /// Build over `dataset` sharded by `part`: encode every row once
    /// with `codec`, then seed the hot tier with the top-K
    /// highest-degree vertices, `K = hot_bytes / (dim × 4)` (decoded
    /// rows are what the hot tier holds). `hot_bytes == 0` disables the
    /// hot tier (and the prefetch annex with it).
    pub fn build(
        dataset: &Dataset,
        part: &Partition,
        codec: Codec,
        hot_bytes: usize,
    ) -> TieredStore {
        let n = dataset.graph.num_vertices();
        let dim = dataset.feat_dim;
        let row_bytes = codec.row_bytes(dim);
        let num_shards = part.num_parts;
        let mut shard_of = vec![0u32; n];
        let mut row_of = vec![0u32; n];
        let mut shards: Vec<Vec<u8>> = vec![Vec::new(); num_shards];
        let mut row = vec![0f32; dim];
        for v in 0..n {
            let s = part.part_of(v as VertexId);
            shard_of[v] = s as u32;
            row_of[v] = (shards[s].len() / row_bytes) as u32;
            dataset.write_features(v as VertexId, &mut row);
            codec.encode_row(&row, &mut shards[s]);
        }

        // hot set: deterministic top-K by (degree desc, id asc) — the
        // frequency proxy both the samplers and the serve workload skew
        // toward
        let k = (hot_bytes / (dim * 4)).min(n);
        let mut hot_pos = vec![NOT_HOT; n];
        let mut hot_rows = Vec::with_capacity(k * dim);
        if k > 0 {
            let mut order: Vec<u32> = (0..n as u32).collect();
            order.sort_by_key(|&v| {
                (std::cmp::Reverse(dataset.graph.neighbors(v).len()), v)
            });
            order.truncate(k);
            for (i, &v) in order.iter().enumerate() {
                hot_pos[v as usize] = i as u32;
                // decode from the *encoded* bytes so the hot tier serves
                // exactly what a cold fill would
                let start = hot_rows.len();
                hot_rows.resize(start + dim, 0.0);
                let s = shard_of[v as usize] as usize;
                let off = row_of[v as usize] as usize * row_bytes;
                codec.decode_row(&shards[s][off..off + row_bytes], &mut hot_rows[start..]);
            }
        }
        let annex_cap = if k == 0 { 0 } else { (k / 4).max(1) };
        TieredStore {
            dim,
            codec,
            row_bytes,
            shard_of,
            row_of,
            shards,
            hot_pos,
            hot_rows,
            annex: Mutex::new(Annex {
                cap: annex_cap,
                map: HashMap::new(),
                slots: vec![0f32; annex_cap * dim],
                owner: vec![NOT_HOT; annex_cap],
                cursor: 0,
            }),
        }
    }

    /// Single-shard build (the training path's store shape).
    pub fn single(dataset: &Dataset, codec: Codec, hot_bytes: usize) -> TieredStore {
        let part = Partition {
            assignment: vec![0u16; dataset.graph.num_vertices()],
            num_parts: 1,
        };
        TieredStore::build(dataset, &part, codec, hot_bytes)
    }

    /// Rows the static hot tier holds.
    pub fn hot_rows(&self) -> usize {
        self.hot_rows.len() / self.dim.max(1)
    }

    /// Prefetch-annex capacity in rows (0 when the hot tier is off).
    pub fn annex_cap(&self) -> usize {
        self.annex.lock().unwrap().cap
    }

    /// Resident bytes: encoded cold shards + decoded hot tier + annex.
    pub fn total_bytes(&self) -> usize {
        let cold: usize = self.shards.iter().map(|s| s.len()).sum();
        let hot = (self.hot_rows.len() + self.annex.lock().unwrap().slots.len()) * 4;
        cold + hot
    }

    fn encoded(&self, v: VertexId) -> &[u8] {
        let s = self.shard_of[v as usize] as usize;
        let off = self.row_of[v as usize] as usize * self.row_bytes;
        &self.shards[s][off..off + self.row_bytes]
    }
}

impl FeatureStore for TieredStore {
    fn dim(&self) -> usize {
        self.dim
    }

    fn codec(&self) -> Codec {
        self.codec
    }

    fn row_bytes(&self) -> usize {
        self.row_bytes
    }

    fn tier_of(&self, v: VertexId) -> Tier {
        if self.hot_pos[v as usize] != NOT_HOT {
            return Tier::Hot;
        }
        let annex = self.annex.lock().unwrap();
        if annex.cap > 0 && annex.map.contains_key(&v) {
            Tier::Hot
        } else {
            Tier::Cold
        }
    }

    fn copy_row(&self, v: VertexId, out: &mut [f32]) {
        let pos = self.hot_pos[v as usize];
        if pos != NOT_HOT {
            let start = pos as usize * self.dim;
            out.copy_from_slice(&self.hot_rows[start..start + self.dim]);
            return;
        }
        {
            let annex = self.annex.lock().unwrap();
            if let Some(&slot) = annex.map.get(&v) {
                out.copy_from_slice(&annex.slots[slot * self.dim..(slot + 1) * self.dim]);
                return;
            }
        }
        self.codec.decode_row(self.encoded(v), out);
    }

    fn copy_encoded_row(&self, v: VertexId, out: &mut Vec<u8>) {
        // straight byte copy from the cold shard — the wire truth, no
        // re-encode (re-quantizing a decoded row would drift)
        out.clear();
        out.extend_from_slice(self.encoded(v));
    }

    fn prefetch_into_hot(&self, vs: &[VertexId], budget_rows: usize) -> u64 {
        let mut annex = self.annex.lock().unwrap();
        if annex.cap == 0 || budget_rows == 0 {
            return 0;
        }
        let mut fetched = 0u64;
        for &v in vs {
            if fetched as usize >= budget_rows {
                break;
            }
            if self.hot_pos[v as usize] != NOT_HOT || annex.map.contains_key(&v) {
                continue; // already hot
            }
            let slot = annex.cursor;
            let evicted = annex.owner[slot];
            if evicted != NOT_HOT {
                annex.map.remove(&evicted);
            }
            let dim = self.dim;
            let enc = self.encoded(v);
            self.codec.decode_row(enc, &mut annex.slots[slot * dim..(slot + 1) * dim]);
            annex.owner[slot] = v;
            annex.map.insert(v, slot);
            annex.cursor = (slot + 1) % annex.cap;
            fetched += 1;
        }
        fetched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{datasets, partition};

    fn fixture() -> (Dataset, Partition) {
        let ds = datasets::build("tiny", 5).unwrap();
        let part = partition::random(&ds.graph, 3, 2);
        (ds, part)
    }

    #[test]
    fn cold_tier_serves_decoded_rows_within_codec_bounds() {
        let (ds, part) = fixture();
        let mut truth = vec![0f32; ds.feat_dim];
        for codec in Codec::all() {
            let store = TieredStore::build(&ds, &part, codec, 0);
            assert_eq!(store.row_bytes(), codec.row_bytes(ds.feat_dim));
            let mut got = vec![0f32; ds.feat_dim];
            for v in [0u32, 7, 999, 1999] {
                ds.write_features(v, &mut truth);
                store.copy_row(v, &mut got);
                match codec {
                    Codec::F32 => assert_eq!(got, truth, "f32 must be exact"),
                    _ => {
                        for (a, b) in truth.iter().zip(&got) {
                            // tiny's features are U(-1,1): both codecs
                            // stay well inside 1% absolute here
                            assert!((a - b).abs() < 0.01, "{codec:?} v{v}: {a} vs {b}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn hot_tier_serves_identical_values_to_cold() {
        let (ds, part) = fixture();
        for codec in Codec::all() {
            let hot = TieredStore::build(&ds, &part, codec, 64 * 1024);
            let cold = TieredStore::build(&ds, &part, codec, 0);
            assert!(hot.hot_rows() > 0, "64 KiB must fit some dim-16 rows");
            let mut a = vec![0f32; ds.feat_dim];
            let mut b = vec![0f32; ds.feat_dim];
            let mut hot_seen = 0;
            for v in 0..ds.graph.num_vertices() as u32 {
                hot.copy_row(v, &mut a);
                cold.copy_row(v, &mut b);
                let bits = |r: &[f32]| r.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a), bits(&b), "{codec:?} v{v}: tiers must agree bitwise");
                if hot.tier_of(v) == Tier::Hot {
                    hot_seen += 1;
                }
            }
            assert_eq!(hot_seen, hot.hot_rows(), "static hot set classification");
        }
    }

    #[test]
    fn hot_set_is_top_degree_and_capacity_bounded() {
        let (ds, part) = fixture();
        let budget = 32 * ds.feat_dim * 4; // exactly 32 decoded rows
        let store = TieredStore::build(&ds, &part, Codec::Int8, budget);
        assert_eq!(store.hot_rows(), 32);
        // every hot vertex has degree >= every cold vertex's degree
        let min_hot = (0..ds.graph.num_vertices() as u32)
            .filter(|&v| store.hot_pos[v as usize] != NOT_HOT)
            .map(|v| ds.graph.neighbors(v).len())
            .min()
            .unwrap();
        let max_cold = (0..ds.graph.num_vertices() as u32)
            .filter(|&v| store.hot_pos[v as usize] == NOT_HOT)
            .map(|v| ds.graph.neighbors(v).len())
            .max()
            .unwrap();
        assert!(min_hot >= max_cold, "hot tier must hold the top-degree vertices");
    }

    #[test]
    fn encoded_row_copy_matches_shard_bytes() {
        let (ds, part) = fixture();
        let store = TieredStore::build(&ds, &part, Codec::Int8, 4096);
        let mut enc = Vec::new();
        for v in [3u32, 500, 1500] {
            store.copy_encoded_row(v, &mut enc);
            assert_eq!(enc.len(), store.row_bytes());
            assert_eq!(&enc[..], store.encoded(v), "wire bytes, not a re-encode");
        }
    }

    #[test]
    fn prefetch_annex_promotes_and_evicts_fifo() {
        let (ds, part) = fixture();
        let budget = 40 * ds.feat_dim * 4;
        let store = TieredStore::build(&ds, &part, Codec::Fp16, budget);
        let cap = store.annex_cap();
        assert!(cap >= 1);
        // pick cold vertices to promote
        let cold: Vec<u32> = (0..ds.graph.num_vertices() as u32)
            .filter(|&v| store.tier_of(v) == Tier::Cold)
            .take(cap + 2)
            .collect();
        assert!(cold.len() > cap, "need enough cold vertices to overflow the annex");
        let fetched = store.prefetch_into_hot(&cold, cold.len());
        assert_eq!(fetched as usize, cold.len(), "all requested rows promoted");
        // the ring kept only the last `cap`; the first promotions aged out
        assert_eq!(store.tier_of(cold[0]), Tier::Cold, "FIFO eviction");
        assert_eq!(store.tier_of(*cold.last().unwrap()), Tier::Hot);
        // promoted rows serve the same bytes as a cold decode
        let reference = TieredStore::build(&ds, &part, Codec::Fp16, budget);
        let mut a = vec![0f32; ds.feat_dim];
        let mut b = vec![0f32; ds.feat_dim];
        let v = *cold.last().unwrap();
        store.copy_row(v, &mut a);
        reference.copy_row(v, &mut b);
        assert_eq!(a, b, "annex must serve the decoded cold bytes verbatim");
        // budget of zero is a no-op
        assert_eq!(store.prefetch_into_hot(&cold, 0), 0);
    }

    #[test]
    fn single_shard_matches_partitioned_values() {
        let (ds, part) = fixture();
        let a = TieredStore::build(&ds, &part, Codec::Int8, 0);
        let b = TieredStore::single(&ds, Codec::Int8, 0);
        let mut ra = vec![0f32; ds.feat_dim];
        let mut rb = vec![0f32; ds.feat_dim];
        for v in [0u32, 123, 1999] {
            a.copy_row(v, &mut ra);
            b.copy_row(v, &mut rb);
            assert_eq!(ra, rb, "sharding must not change row content");
        }
        assert!(b.total_bytes() >= ds.graph.num_vertices() * b.row_bytes());
    }
}
