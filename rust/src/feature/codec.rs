//! Row codecs: the wire format of one feature row.
//!
//! A [`Codec`] fixes how a `dim`-element f32 feature row is laid out in
//! storage and on the fabric. Rows are encoded **once** at store build
//! ([`super::TieredStore`]) and decoded on gather; every byte ledger in
//! the system (`feat_storage_bytes`, `feat_fabric_bytes`, cache arenas,
//! serve bytes/request) charges [`Codec::row_bytes`] — the exact encoded
//! size — so compression shows up as *wire* bytes, not a modeled ratio.
//!
//! | codec | layout                                  | row bytes | error bound            |
//! |-------|-----------------------------------------|-----------|------------------------|
//! | f32   | `dim × f32` (LE)                        | `4·dim`   | exact (bit-identical)  |
//! | fp16  | `dim × binary16` (LE, round-to-nearest-even) | `2·dim` | `max(2⁻¹¹·|x|, 2⁻²⁴)` |
//! | int8  | `[scale: f32 LE][zp: u8][dim × u8]`     | `dim + 5` | `scale/2` per element  |
//!
//! The int8 quantizer is per-row affine with a *nudged* range: the
//! represented interval is `[min(lo,0), max(hi,0)]` so the zero point is
//! always representable (`x̂ = scale·(q − zp)` with `q = clamp(round(x/
//! scale + zp), 0, 255)`); an all-zero row encodes the sentinel
//! `scale == 0`. Decoding is a pure function of the encoded bytes, so
//! owner-side and requester-side decodes of the same row are
//! bit-identical — the property the cooperative fabric path relies on.

/// The wire format of one feature row (CLI `--codec f32|fp16|int8`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Passthrough: rows stay f32, bit-identical to the uncompressed
    /// store (the default — every PR-6 ledger and checksum is preserved).
    F32,
    /// IEEE 754 binary16 per element, round-to-nearest-even.
    Fp16,
    /// Per-row affine u8 quantization with an f32 scale and a u8 zero
    /// point header.
    Int8,
}

impl Codec {
    pub fn name(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::Fp16 => "fp16",
            Codec::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<Codec> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "float32" | "none" => Some(Codec::F32),
            "fp16" | "f16" | "half" => Some(Codec::Fp16),
            "int8" | "i8" | "u8" => Some(Codec::Int8),
            _ => None,
        }
    }

    /// All codecs, in CLI order (repro sweeps iterate this).
    pub fn all() -> [Codec; 3] {
        [Codec::F32, Codec::Fp16, Codec::Int8]
    }

    /// Exact encoded size of one `dim`-element row — the number every
    /// byte ledger charges per stored/shipped row.
    pub fn row_bytes(&self, dim: usize) -> usize {
        match self {
            Codec::F32 => dim * 4,
            Codec::Fp16 => dim * 2,
            Codec::Int8 => dim + 5,
        }
    }

    /// Append the encoded form of `row` to `out` (exactly
    /// [`Codec::row_bytes`] bytes).
    pub fn encode_row(&self, row: &[f32], out: &mut Vec<u8>) {
        match self {
            Codec::F32 => {
                for &x in row {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Codec::Fp16 => {
                for &x in row {
                    out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
                }
            }
            Codec::Int8 => encode_int8(row, out),
        }
    }

    /// Decode one encoded row (`bytes.len() == row_bytes(out.len())`)
    /// into `out`.
    pub fn decode_row(&self, bytes: &[u8], out: &mut [f32]) {
        match self {
            Codec::F32 => {
                debug_assert_eq!(bytes.len(), out.len() * 4);
                for (o, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                    *o = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            Codec::Fp16 => {
                debug_assert_eq!(bytes.len(), out.len() * 2);
                for (o, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                    *o = f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]]));
                }
            }
            Codec::Int8 => decode_int8(bytes, out),
        }
    }
}

/// Per-row affine u8 quantization: `[scale: f32 LE][zp: u8][dim × u8]`.
/// The range is nudged to include 0 (`lo = min(row), 0`; `hi = max(row),
/// 0`) so `zp = round(−lo/scale)` lands in `[0, 255]` without clamping
/// and zero round-trips exactly; `scale == 0` is the all-zero sentinel.
fn encode_int8(row: &[f32], out: &mut Vec<u8>) {
    let mut lo = 0f32;
    let mut hi = 0f32;
    for &x in row {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let scale = (hi - lo) / 255.0;
    if scale == 0.0 {
        out.extend_from_slice(&0f32.to_le_bytes());
        out.push(0);
        out.resize(out.len() + row.len(), 0);
        return;
    }
    let zp = (-lo / scale).round().clamp(0.0, 255.0);
    out.extend_from_slice(&scale.to_le_bytes());
    out.push(zp as u8);
    for &x in row {
        let q = (x / scale + zp).round().clamp(0.0, 255.0);
        out.push(q as u8);
    }
}

fn decode_int8(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), out.len() + 5);
    let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    let zp = bytes[4] as f32;
    for (o, &q) in out.iter_mut().zip(&bytes[5..]) {
        *o = scale * (q as f32 - zp);
    }
}

/// f32 → binary16 with round-to-nearest-even (normal, subnormal,
/// overflow-to-Inf, and NaN paths — no `half` crate in the offline
/// build).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN signaling-agnostic: force a payload bit)
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → Inf
    }
    if unbiased >= -14 {
        // normal range: keep 10 mantissa bits, RNE on the 13 dropped
        let mut m = mant >> 13;
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1; // carry may roll into the exponent (and to Inf) — the
                    // packed add below handles both correctly
        }
        let e = (unbiased + 15) as u32;
        return sign | ((e << 10) + m) as u16;
    }
    if unbiased < -25 {
        return sign; // below half the smallest subnormal → ±0
    }
    // subnormal: shift the 24-bit significand down, RNE on dropped bits
    let s24 = 0x0080_0000 | mant;
    let shift = (-(unbiased + 1)) as u32; // in [14, 24]
    let mut m = s24 >> shift;
    let rem = s24 & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    if rem > halfway || (rem == halfway && (m & 1) == 1) {
        m += 1; // may carry to 0x400 — exactly the smallest normal
    }
    sign | m as u16
}

/// binary16 → f32 (exact — every f16 value is representable).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // Inf / NaN
    } else if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // subnormal: renormalize into an f32 normal
            let mut e = 113u32; // 127 - 14
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(codec: Codec, row: &[f32]) -> Vec<f32> {
        let mut enc = Vec::new();
        codec.encode_row(row, &mut enc);
        assert_eq!(enc.len(), codec.row_bytes(row.len()), "{codec:?} encoded size");
        let mut out = vec![0f32; row.len()];
        codec.decode_row(&enc, &mut out);
        out
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for c in Codec::all() {
            assert_eq!(Codec::parse(c.name()), Some(c));
        }
        assert_eq!(Codec::parse("half"), Some(Codec::Fp16));
        assert_eq!(Codec::parse("nope"), None);
    }

    #[test]
    fn row_bytes_are_exact() {
        assert_eq!(Codec::F32.row_bytes(16), 64);
        assert_eq!(Codec::Fp16.row_bytes(16), 32);
        assert_eq!(Codec::Int8.row_bytes(16), 21);
        // the tiny dataset's dim-16 rows already clear the 3x bar
        assert!(64.0 / 21.0 >= 3.0);
    }

    #[test]
    fn f32_codec_is_bit_identical() {
        let row = [1.5f32, -0.25, 1e-30, f32::MIN_POSITIVE, -3.7e8, 0.0];
        let out = roundtrip(Codec::F32, &row);
        for (a, b) in row.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn fp16_known_values_and_bound() {
        // exactly representable values round-trip exactly
        for &x in &[0.0f32, 1.0, -2.0, 0.5, 65504.0, -0.09997559] {
            let h = f32_to_f16_bits(x);
            if x == 65504.0 {
                assert_eq!(h, 0x7bff, "largest normal f16");
            }
        }
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0)), 1.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-0.5)), -0.5);
        // subnormals: smallest positive f16 is 2^-24
        assert_eq!(f16_bits_to_f32(0x0001), 2f32.powi(-24));
        assert_eq!(f32_to_f16_bits(2f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2f32.powi(-26)), 0, "below half-ulp of subnormal → 0");
        // overflow → Inf
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
        // the relative bound on a sweep of awkward values
        let mut x = -7.9997f32;
        while x < 8.0 {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let bound = (x.abs() * 2f32.powi(-11)).max(2f32.powi(-24));
            assert!((y - x).abs() <= bound, "fp16 bound: {x} -> {y}");
            x += 0.01703;
        }
    }

    #[test]
    fn fp16_rne_ties_go_to_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next f16 (1 + 2^-10):
        // RNE keeps the even mantissa (1.0)
        let tie = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tie)), 1.0);
        // 1 + 3·2^-11 ties upward to 1 + 2^-9's even neighbor 1 + 2·2^-10
        let tie_up = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tie_up)), 1.0 + 2.0 * 2f32.powi(-10));
    }

    #[test]
    fn int8_error_within_half_scale() {
        let row: Vec<f32> = (0..64).map(|i| ((i * 37 + 11) % 97) as f32 / 17.0 - 2.5).collect();
        let mut enc = Vec::new();
        Codec::Int8.encode_row(&row, &mut enc);
        let scale = f32::from_le_bytes([enc[0], enc[1], enc[2], enc[3]]);
        assert!(scale > 0.0);
        let out = roundtrip(Codec::Int8, &row);
        for (a, b) in row.iter().zip(&out) {
            assert!(
                (a - b).abs() <= scale * 0.5 * (1.0 + 1e-3),
                "int8 bound: {a} -> {b} (scale {scale})"
            );
        }
    }

    #[test]
    fn int8_zero_row_and_zero_point_are_exact() {
        let zeros = vec![0f32; 10];
        let out = roundtrip(Codec::Int8, &zeros);
        assert_eq!(out, zeros, "all-zero sentinel row");
        // zero inside a mixed row decodes to exactly zero (nudged range)
        let row = [0.0f32, 1.0, -1.0, 0.73];
        let out = roundtrip(Codec::Int8, &row);
        assert_eq!(out[0], 0.0, "zero point must be exact");
    }

    #[test]
    fn int8_one_sided_rows_keep_zero_in_range() {
        // all-positive and all-negative rows: the nudge keeps lo/hi
        // anchored at 0, so q stays in range without zp clamping
        for row in [vec![0.5f32, 1.0, 2.0], vec![-0.5f32, -1.0, -2.0]] {
            let mut enc = Vec::new();
            Codec::Int8.encode_row(&row, &mut enc);
            let scale = f32::from_le_bytes([enc[0], enc[1], enc[2], enc[3]]);
            let out = roundtrip(Codec::Int8, &row);
            for (a, b) in row.iter().zip(&out) {
                assert!((a - b).abs() <= scale * 0.5 * (1.0 + 1e-3), "{a} -> {b}");
            }
        }
    }

    #[test]
    fn decode_is_pure_and_repeatable() {
        // the cooperative fabric ships encoded bytes: owner-side and
        // requester-side decodes of the same bytes must agree bitwise
        let row: Vec<f32> = (0..33).map(|i| (i as f32 * 0.917).sin()).collect();
        for codec in Codec::all() {
            let mut enc = Vec::new();
            codec.encode_row(&row, &mut enc);
            let mut a = vec![0f32; row.len()];
            let mut b = vec![0f32; row.len()];
            codec.decode_row(&enc, &mut a);
            codec.decode_row(&enc, &mut b);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "{codec:?}");
        }
    }
}
