//! LRU cache benchmarks: access throughput in the hit-heavy, miss-heavy,
//! and thrash regimes (the feature-loading stage consults the cache once
//! per requested vertex row).

use coopgnn::coop::cache::LruCache;
use coopgnn::util::rng::Pcg64;
use coopgnn::util::stats::{bench_ms, smoke_mode};

fn main() {
    let smoke = smoke_mode();
    let n_access = if smoke { 10_000usize } else { 100_000 };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 30) };

    // hit-heavy: universe fits in cache
    let mut c = LruCache::new(1 << 16);
    let mut rng = Pcg64::new(1);
    let keys: Vec<u32> = (0..n_access).map(|_| rng.next_below(1 << 15) as u32).collect();
    let s = bench_ms("lru/hit_heavy", warmup, iters, || {
        for &k in &keys {
            std::hint::black_box(c.access(k));
        }
    });
    println!("  -> {:.1} M accesses/s", n_access as f64 / (s.p50 / 1e3) / 1e6);

    // miss-heavy: huge universe
    let mut c = LruCache::new(1 << 14);
    let keys: Vec<u32> = (0..n_access).map(|_| rng.next_below(1 << 24) as u32).collect();
    let s = bench_ms("lru/miss_heavy", warmup, iters, || {
        for &k in &keys {
            std::hint::black_box(c.access(k));
        }
    });
    println!("  -> {:.1} M accesses/s", n_access as f64 / (s.p50 / 1e3) / 1e6);

    // cyclic thrash: worst case eviction churn
    let mut c = LruCache::new(10_000);
    let keys: Vec<u32> = (0..n_access).map(|i| (i % 10_001) as u32).collect();
    let s = bench_ms("lru/cyclic_thrash", warmup, iters, || {
        for &k in &keys {
            std::hint::black_box(c.access(k));
        }
    });
    println!("  -> {:.1} M accesses/s", n_access as f64 / (s.p50 / 1e3) / 1e6);
}
