//! Serving-plane benchmark: fixed vs adaptive admission under equal
//! offered load, independent vs cooperative batching — real CPU cost of
//! the simulation (the executor's sampling + gathering + prediction
//! work) next to the virtual-time scorecard (p50/p99, req/s,
//! bytes/request). Merges a `serve` section into `BENCH_pipeline.json`
//! (stamped with schema version + seed recipe) so the serving numbers
//! are tracked across PRs alongside `bench_coop`/`bench_train_step`.
//!
//! `cargo bench --bench bench_serve` (full) / `-- --test` (CI smoke).

use coopgnn::coop::engine::Mode;
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::serve::{BatcherKind, ServeConfig};
use coopgnn::util::json::{merge_section, stamped, Json};
use coopgnn::util::stats::{smoke_mode, Timer};
use std::collections::BTreeMap;
use std::path::Path;

fn main() {
    let smoke = smoke_mode();
    const SEED: u64 = 7;
    let (ds_name, pes, rate, slo_us, fixed_per_pe, duration): (_, usize, f64, u64, usize, usize) =
        if smoke {
            ("tiny", 2, 20_000.0, 30_000, 16, 8)
        } else {
            ("flickr-s", 4, 20_000.0, 50_000, 64, 32)
        };

    let mut section = BTreeMap::new();
    section.insert("dataset".to_string(), Json::Str(ds_name.to_string()));
    section.insert("pes".to_string(), Json::Num(pes as f64));
    section.insert("rate_per_s".to_string(), Json::Num(rate));
    section.insert("slo_ms".to_string(), Json::Num(slo_us as f64 / 1e3));
    section.insert("duration_batches".to_string(), Json::Num(duration as f64));
    section.insert("smoke".to_string(), Json::Bool(smoke));

    let mut adaptive_coop_bytes = 0.0f64;
    let mut fixed_indep_bytes = 0.0f64;
    for mode in [Mode::Independent, Mode::Cooperative] {
        let pipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(mode)
            .num_pes(pes)
            .seed(SEED)
            .build()
            .expect("registry dataset");
        for batcher in [BatcherKind::Fixed, BatcherKind::Adaptive] {
            let scfg = ServeConfig {
                rate_per_s: rate,
                slo_us,
                batcher,
                duration_batches: duration,
                fixed_batch_per_pe: fixed_per_pe,
                ..Default::default()
            };
            let t = Timer::start();
            let out = pipe.server(scfg).expect("valid serve config").run();
            let sim_ms = t.elapsed_ms();
            let r = out.report;
            let label = format!("{}_{}", mode.name().to_lowercase(), batcher.name());
            println!(
                "serve/{ds_name}_{pes}pe {label:<16} served {:>6} in {:>3} batches \
                 (mean {:>6.1}) | p50 {:>7.2} p99 {:>7.2} ms | {:>6.0} req/s | {:>7.0} \
                 B/req | sim {sim_ms:>8.1} ms CPU (executor {:>7.1} ms)",
                r.served,
                r.batches,
                r.mean_batch,
                r.p50_ms,
                r.p99_ms,
                r.requests_per_s,
                r.bytes_per_req(),
                out.exec_wall_ms
            );
            if mode == Mode::Cooperative && batcher == BatcherKind::Adaptive {
                adaptive_coop_bytes = r.bytes_per_req();
                // Flight-recorder summary for the headline arm: span
                // counts plus the attributed-vs-ledger byte
                // reconciliation (the integration-test invariant,
                // re-checked on the bench config and stamped so drift
                // shows up in the tracked artifact).
                let trace = out.ledger.trace();
                let attributed = trace.stage_bytes("serve_storage")
                    + trace.stage_bytes("serve_fabric")
                    + trace.stage_bytes("serve_hot");
                let ledger_total: u64 = out
                    .ledger
                    .batches
                    .iter()
                    .map(|b| b.storage_bytes + b.fabric_bytes + b.hot_bytes)
                    .sum();
                let mut ts = BTreeMap::new();
                ts.insert("spans".to_string(), Json::Num(trace.span_count() as f64));
                ts.insert(
                    "spans_per_batch".to_string(),
                    Json::Num(trace.span_count() as f64 / trace.batch_count().max(1) as f64),
                );
                ts.insert("bytes_attributed".to_string(), Json::Num(attributed as f64));
                ts.insert("bytes_in_ledger".to_string(), Json::Num(ledger_total as f64));
                ts.insert("reconciled".to_string(), Json::Bool(attributed == ledger_total));
                section.insert("trace_summary".to_string(), Json::Obj(ts));
            }
            if mode == Mode::Independent && batcher == BatcherKind::Fixed {
                fixed_indep_bytes = r.bytes_per_req();
            }
            let mut arm = BTreeMap::new();
            arm.insert("served".to_string(), Json::Num(r.served as f64));
            arm.insert("mean_batch".to_string(), Json::Num(r.mean_batch));
            arm.insert("p50_ms".to_string(), Json::Num(r.p50_ms));
            arm.insert("p90_ms".to_string(), Json::Num(r.p90_ms));
            arm.insert("p99_ms".to_string(), Json::Num(r.p99_ms));
            arm.insert("requests_per_s".to_string(), Json::Num(r.requests_per_s));
            arm.insert("bytes_per_req".to_string(), Json::Num(r.bytes_per_req()));
            arm.insert("slo_violation_rate".to_string(), Json::Num(r.slo_violation_rate));
            arm.insert("sim_cpu_ms".to_string(), Json::Num(sim_ms));
            arm.insert("executor_cpu_ms".to_string(), Json::Num(out.exec_wall_ms));
            section.insert(label, Json::Obj(arm));
        }
    }
    let gain =
        if adaptive_coop_bytes > 0.0 { fixed_indep_bytes / adaptive_coop_bytes } else { 0.0 };
    println!(
        "serve/{ds_name}_{pes}pe bytes-per-request check: fixed-indep {fixed_indep_bytes:.0} vs \
         adaptive-coop {adaptive_coop_bytes:.0} -> {gain:.2}x: {}",
        if gain > 1.0 {
            "COOPERATIVE (adaptive coop moves fewer bytes per request at equal load)"
        } else {
            "WARNING: no bytes-per-request win (config too small?)"
        }
    );
    section.insert("adaptive_coop_bytes_gain".to_string(), Json::Num(gain));

    let path = Path::new("BENCH_pipeline.json");
    match merge_section(path, "serve", stamped(SEED, section)) {
        Ok(()) => println!("bench_serve: wrote section `serve` to {}", path.display()),
        Err(e) => eprintln!("bench_serve: could not write {}: {e}", path.display()),
    }
}
