//! Sampler micro-benchmarks: per-algorithm layer-sampling throughput.
//! The sampling stage is a per-batch hot path on L3 (paper Table 4
//! "Samp." column); the perf target (EXPERIMENTS.md §Perf) is >10M
//! examined-edges/s/core for NS/LABOR-0.

use coopgnn::graph::generate;
use coopgnn::sampling::{Neighborhoods, RwParams, SamplerConfig, SamplerKind};
use coopgnn::util::stats::{bench_ms, smoke_mode};

fn main() {
    let smoke = smoke_mode();
    let nv: usize = if smoke { 20_000 } else { 89_200 };
    let n_seeds: u32 = if smoke { 512 } else { 4096 };
    let g = generate::chung_lu(nv, 10.1, 2.5, 1);
    let seeds: Vec<u32> = (0..n_seeds).map(|i| i * 19 % nv as u32).collect();
    // examined edges = sum of seed degrees (the samplers scan full lists)
    let examined: usize = seeds.iter().map(|&s| g.degree(s)).sum();
    println!(
        "graph |V|={} |E|={}, {n_seeds} seeds, {examined} examined edges",
        g.num_vertices(),
        g.num_edges()
    );

    for kind in SamplerKind::ALL {
        let cfg = SamplerConfig {
            rw: RwParams { num_walks: 25, ..Default::default() },
            ..Default::default()
        };
        let mut s = cfg.build(kind, &g, 7);
        let mut out = Neighborhoods::default();
        let iters = match (smoke, kind == SamplerKind::RandomWalk) {
            (true, _) => 3,
            (false, true) => 10,
            (false, false) => 50,
        };
        let summary = bench_ms(&format!("sample_layer/{}", kind.name()), 3, iters, || {
            s.sample_layer(&seeds, 0, &mut out);
            s.advance_batch();
        });
        let meps = examined as f64 / (summary.p50 / 1e3) / 1e6;
        println!("  -> {:.1} M examined-edges/s ({} sampled)", meps, out.num_edges());
    }

    // dependent-RNG variants: the smoothing path costs two hashes + two
    // icdf + one cdf per variate — measure the overhead vs κ=1.
    for kappa in ["1", "64"] {
        let cfg = SamplerConfig {
            kappa: coopgnn::sampling::Kappa::parse(kappa).unwrap(),
            ..Default::default()
        };
        let mut s = cfg.build(SamplerKind::Labor0, &g, 9);
        s.advance_batch(); // move off the pure-z1 fast path for κ=64
        let mut out = Neighborhoods::default();
        let iters = if smoke { 3 } else { 50 };
        let warm = if smoke { 1 } else { 3 };
        bench_ms(&format!("sample_layer/LABOR-0 kappa={kappa}"), warm, iters, || {
            s.sample_layer(&seeds, 0, &mut out);
        });
    }
}
