//! Sampler micro-benchmarks: per-algorithm layer-sampling throughput.
//! The sampling stage is a per-batch hot path on L3 (paper Table 4
//! "Samp." column); the perf target (EXPERIMENTS.md §Perf) is >10M
//! examined-edges/s/core for NS/LABOR-0.

use coopgnn::graph::generate;
use coopgnn::sampling::{Neighborhoods, RwParams, SamplerConfig, SamplerKind};
use coopgnn::util::stats::bench_ms;

fn main() {
    let g = generate::chung_lu(89_200, 10.1, 2.5, 1);
    let seeds: Vec<u32> = (0..4096u32).map(|i| i * 19 % 89_200).collect();
    // examined edges = sum of seed degrees (the samplers scan full lists)
    let examined: usize = seeds.iter().map(|&s| g.degree(s)).sum();
    println!("graph |V|={} |E|={}, 4096 seeds, {examined} examined edges", g.num_vertices(), g.num_edges());

    for kind in SamplerKind::ALL {
        let cfg = SamplerConfig {
            rw: RwParams { num_walks: 25, ..Default::default() },
            ..Default::default()
        };
        let mut s = cfg.build(kind, &g, 7);
        let mut out = Neighborhoods::default();
        let iters = if kind == SamplerKind::RandomWalk { 10 } else { 50 };
        let summary = bench_ms(&format!("sample_layer/{}", kind.name()), 3, iters, || {
            s.sample_layer(&seeds, 0, &mut out);
            s.advance_batch();
        });
        let meps = examined as f64 / (summary.p50 / 1e3) / 1e6;
        println!("  -> {:.1} M examined-edges/s ({} sampled)", meps, out.num_edges());
    }

    // dependent-RNG variants: the smoothing path costs two hashes + two
    // icdf + one cdf per variate — measure the overhead vs κ=1.
    for kappa in ["1", "64"] {
        let cfg = SamplerConfig {
            kappa: coopgnn::sampling::Kappa::parse(kappa).unwrap(),
            ..Default::default()
        };
        let mut s = cfg.build(SamplerKind::Labor0, &g, 9);
        s.advance_batch(); // move off the pure-z1 fast path for κ=64
        let mut out = Neighborhoods::default();
        bench_ms(&format!("sample_layer/LABOR-0 kappa={kappa}"), 3, 50, || {
            s.sample_layer(&seeds, 0, &mut out);
        });
    }
}
