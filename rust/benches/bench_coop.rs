//! Cooperative-engine benchmarks: Algorithm 1 sampling rounds, the
//! all-to-all fabric, and the cooperative vs independent end-to-end
//! count phase (the inner loop behind Tables 4/7).

use coopgnn::coop::all_to_all::Exchange;
use coopgnn::coop::coop_sampler::{partition_seeds, sample_cooperative};
use coopgnn::coop::indep::sample_independent;
use coopgnn::graph::{generate, partition};
use coopgnn::sampling::{SamplerConfig, SamplerKind};
use coopgnn::util::rng::Pcg64;
use coopgnn::util::stats::bench_ms;

fn main() {
    let g = generate::chung_lu(89_200, 10.1, 2.5, 1);
    let part = partition::random(&g, 4, 2);
    let cfg = SamplerConfig::default();
    let seeds: Vec<u32> = (0..4096u32).map(|i| i * 19 % 89_200).collect();
    let per_pe = partition_seeds(&seeds, &part);

    bench_ms("coop_sample/4pe_b1024_labor0", 2, 15, || {
        let mut samplers: Vec<_> =
            (0..4).map(|_| cfg.build(SamplerKind::Labor0, &g, 7)).collect();
        let c = sample_cooperative(&g, &part, &mut samplers, &per_pe, 3);
        std::hint::black_box(&c);
    });

    bench_ms("indep_sample/4pe_b1024_labor0", 2, 15, || {
        let mut samplers: Vec<_> =
            (0..4).map(|p| cfg.build(SamplerKind::Labor0, &g, 7 + p)).collect();
        let s = sample_independent(&mut samplers, &per_pe);
        std::hint::black_box(&s);
    });

    // raw all-to-all routing throughput
    let mut rng = Pcg64::new(3);
    let buckets: Vec<Vec<Vec<u32>>> = (0..8)
        .map(|_| {
            (0..8)
                .map(|_| (0..20_000).map(|_| rng.next_u64() as u32).collect())
                .collect()
        })
        .collect();
    let items: usize = buckets.iter().flatten().map(|b| b.len()).sum();
    let s = bench_ms("all_to_all/8pe_1.28M_ids", 2, 20, || {
        let mut ex = Exchange::new(8);
        let inboxes = ex.route(&buckets, 4);
        std::hint::black_box(&inboxes);
    });
    println!("  -> {:.1} M ids/s routed", items as f64 / (s.p50 / 1e3) / 1e6);
}
