//! Cooperative-engine benchmarks: Algorithm 1 sampling rounds, the
//! all-to-all fabric, and — the headline — the thread-per-PE engine vs
//! the serial reference, demonstrating real concurrency: with 4 PEs the
//! cooperative batch wall-clock sits well below the summed per-PE stage
//! times (`cargo bench --bench bench_coop`; `-- --test` runs the smoke
//! configuration CI uploads as the perf-trajectory artifact). The engine
//! comparison is constructed through `pipeline::PipelineBuilder`, like
//! every other entry stack.

use coopgnn::coop::all_to_all::{Exchange, Topology};
use coopgnn::coop::coop_sampler::{partition_seeds, sample_cooperative};
use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::coop::indep::sample_independent;
use coopgnn::costmodel::{pick_collective, FabricModel};
use coopgnn::feature::Codec;
use coopgnn::graph::{generate, partition};
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::sampling::{SamplerConfig, SamplerKind};
use coopgnn::util::json::{merge_section, stamped, Json};
use coopgnn::util::rng::Pcg64;
use coopgnn::util::stats::{bench_ms, smoke_mode, Timer};
use std::collections::BTreeMap;
use std::path::Path;

fn main() {
    let smoke = smoke_mode();
    let (nv, deg, n_seeds, warmup, iters) =
        if smoke { (20_000, 8.0, 1024u32, 1, 4) } else { (89_200, 10.1, 4096, 2, 15) };
    let g = generate::chung_lu(nv, deg, 2.5, 1);
    let part = partition::random(&g, 4, 2);
    let cfg = SamplerConfig::default();
    let seeds: Vec<u32> = (0..n_seeds).map(|i| i * 19 % nv as u32).collect();
    let per_pe = partition_seeds(&seeds, &part);

    bench_ms("coop_sample/4pe_labor0_serial_ref", warmup, iters, || {
        let mut samplers: Vec<_> =
            (0..4).map(|_| cfg.build(SamplerKind::Labor0, &g, 7)).collect();
        let c = sample_cooperative(&g, &part, &mut samplers, &per_pe, 3);
        std::hint::black_box(&c);
    });

    bench_ms("indep_sample/4pe_labor0", warmup, iters, || {
        let mut samplers: Vec<_> =
            (0..4).map(|p| cfg.build(SamplerKind::Labor0, &g, 7 + p)).collect();
        let s = sample_independent(&mut samplers, &per_pe);
        std::hint::black_box(&s);
    });

    // raw all-to-all routing throughput (serial reference fabric)
    let mut rng = Pcg64::new(3);
    let bucket_len = if smoke { 2_000 } else { 20_000 };
    let buckets: Vec<Vec<Vec<u32>>> = (0..8)
        .map(|_| {
            (0..8)
                .map(|_| (0..bucket_len).map(|_| rng.next_u64() as u32).collect())
                .collect()
        })
        .collect();
    let items: usize = buckets.iter().flatten().map(|b| b.len()).sum();
    let s = bench_ms("all_to_all/8pe_route", warmup, iters, || {
        let mut ex = Exchange::new(8);
        let inboxes = ex.route(&buckets, 4);
        std::hint::black_box(&inboxes);
    });
    println!("  -> {:.1} M ids/s routed", items as f64 / (s.p50 / 1e3) / 1e6);

    // ---- thread-per-PE engine vs serial reference ----------------------
    // The acceptance demonstration: with num_pes = 4 the cooperative
    // engine runs PEs concurrently. The honest evidence is the serial
    // reference doing *identical work* single-threaded: threaded batch
    // wall-clock must drop below serial batch wall-clock. (Per-PE stage
    // times are also printed, but in threaded mode they include exchange
    // waits, so their sum exceeding the wall is necessary, not
    // sufficient, for real overlap.) Registry dataset so the numbers
    // track a real workload shape across PRs. One PipelineBuilder call
    // stands up the workload; only `cfg.exec` is toggled between runs.
    let (ds_name, b, measure) = if smoke { ("tiny", 128, 3) } else { ("flickr-s", 1024, 8) };
    let mut pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .mode(Mode::Cooperative)
        .num_pes(4)
        .batch_per_pe(b)
        .warmup_batches(1)
        .measure_batches(measure)
        .seed(7)
        .build()
        .expect("registry dataset");
    pipe.cfg.cache_per_pe = Some((pipe.ds.cache_size / 4).max(64));
    // (exec, prefetch) arms; since the feature-plane refactor each batch
    // really moves its feature bytes, so walls include storage + fabric
    // payload movement. wall_batch_ms is producer-side (barrier-to-
    // barrier inside the stream), so prefetch cannot move it — the
    // end-to-end ms/batch (run wall over all batches, consumer side) is
    // the number the prefetch arm exists to track.
    let batches = (1 + measure) as f64;
    let mut arms: Vec<(&str, f64, f64, f64, f64)> = Vec::new();
    for (label, exec, prefetch) in [
        ("serial", ExecMode::Serial, false),
        ("threaded", ExecMode::Threaded, false),
        ("threaded_prefetch", ExecMode::Threaded, true),
    ] {
        pipe.cfg.exec = exec;
        pipe.cfg.prefetch = prefetch;
        let t = Timer::start();
        let r = pipe.engine_report();
        let e2e_ms = t.elapsed_ms() / batches;
        arms.push((label, r.wall_batch_ms, e2e_ms, r.feat_storage_bytes, r.feat_fabric_bytes));
        println!(
            "engine/coop_4pe_{ds_name} exec={:<8} prefetch={} end-to-end {:>7.2} ms/batch | \
             producer wall {:>7.2} ms, per-PE stage sum {:>7.2} ms (sampling {:.2} + feature \
             {:.2}; incl. exchange waits), {:>8.1} KiB from storage, {:>8.1} KiB over fabric",
            exec.name(),
            prefetch as u8,
            e2e_ms,
            r.wall_batch_ms,
            r.wall_sampling_ms + r.wall_feature_ms,
            r.wall_sampling_ms,
            r.wall_feature_ms,
            r.feat_storage_bytes / 1024.0,
            r.feat_fabric_bytes / 1024.0,
        );
    }
    let (serial_wall, threaded_wall) = (arms[0].1, arms[1].1);
    let speedup = if threaded_wall > 0.0 { serial_wall / threaded_wall } else { 0.0 };
    println!(
        "engine/coop_4pe_{ds_name} parallelism check: serial {serial_wall:.2} ms/batch vs \
         threaded {threaded_wall:.2} ms/batch -> {speedup:.2}x: {}",
        if speedup > 1.1 {
            "CONCURRENT (threaded beats the identical-work serial reference)"
        } else {
            "WARNING: no speedup over serial (single-core runner or batch too small?)"
        }
    );
    let prefetch_gain = if arms[2].2 > 0.0 { arms[1].2 / arms[2].2 } else { 0.0 };
    println!(
        "engine/coop_4pe_{ds_name} prefetch check: threaded {:.2} -> prefetch {:.2} \
         end-to-end ms/batch = {prefetch_gain:.2}x",
        arms[1].2, arms[2].2
    );

    // machine-readable perf trajectory: BENCH_pipeline.json, uploaded by
    // CI so batch walls and byte movement are tracked across PRs
    let mut section = BTreeMap::new();
    section.insert("dataset".to_string(), Json::Str(ds_name.to_string()));
    section.insert("pes".to_string(), Json::Num(4.0));
    section.insert("batch_per_pe".to_string(), Json::Num(b as f64));
    section.insert("smoke".to_string(), Json::Bool(smoke));
    for (label, wall, e2e, storage, fabric) in &arms {
        let mut arm = BTreeMap::new();
        arm.insert("wall_batch_ms".to_string(), Json::Num(*wall));
        arm.insert("end_to_end_ms_per_batch".to_string(), Json::Num(*e2e));
        arm.insert("storage_bytes_per_batch".to_string(), Json::Num(*storage));
        arm.insert("fabric_bytes_per_batch".to_string(), Json::Num(*fabric));
        section.insert(label.to_string(), Json::Obj(arm));
    }
    section.insert("threaded_speedup_vs_serial".to_string(), Json::Num(speedup));
    section.insert("prefetch_end_to_end_gain".to_string(), Json::Num(prefetch_gain));
    let path = Path::new("BENCH_pipeline.json");
    // stamped: schema_version + the builder seed recipe, so artifact
    // readers can tell when sections stop being comparable across PRs
    match merge_section(path, "bench_coop", stamped(7, section)) {
        Ok(()) => println!("bench_coop: wrote section `bench_coop` to {}", path.display()),
        Err(e) => eprintln!("bench_coop: could not write {}: {e}", path.display()),
    }

    // ---- tiered storage plane: codec wire bytes + hot-tier hit rate ----
    // Same workload as the engine arms (threaded, prefetch off). Per
    // codec, the cold arm (hot_mb = 0) shows the pure wire-byte ratio on
    // the storage + fabric ledgers; the hot arm adds a degree-seeded hot
    // tier and reports the γ/β split. Counts are codec-invariant, so
    // across codecs only bytes move — the acceptance ratio CI tracks.
    pipe.cfg.exec = ExecMode::Threaded;
    pipe.cfg.prefetch = false;
    let hot_mb = if smoke { 1 } else { 4 };
    let mut tiers = BTreeMap::new();
    tiers.insert("dataset".to_string(), Json::Str(ds_name.to_string()));
    tiers.insert("hot_mb".to_string(), Json::Num(hot_mb as f64));
    tiers.insert("smoke".to_string(), Json::Bool(smoke));
    for codec in Codec::all() {
        pipe.set_codec(codec);
        pipe.set_hot_mb(0);
        let cold = pipe.engine_report();
        pipe.set_hot_mb(hot_mb);
        let hot = pipe.engine_report();
        println!(
            "storage/coop_4pe_{ds_name} codec={:<4} wire {:>4} B/row | cold {:>8.1} KiB \
             storage + {:>8.1} KiB fabric per batch | hot({hot_mb} MiB) hit rate {:.4}, \
             {:>8.1} KiB storage",
            codec.name(),
            pipe.feature_store().row_bytes(),
            cold.feat_storage_bytes / 1024.0,
            cold.feat_fabric_bytes / 1024.0,
            hot.hot_hit_rate,
            hot.feat_storage_bytes / 1024.0,
        );
        let mut arm = BTreeMap::new();
        arm.insert("row_bytes".to_string(), Json::Num(pipe.feature_store().row_bytes() as f64));
        arm.insert("cold_storage_bytes_per_batch".to_string(), Json::Num(cold.feat_storage_bytes));
        arm.insert("cold_fabric_bytes_per_batch".to_string(), Json::Num(cold.feat_fabric_bytes));
        arm.insert("hot_storage_bytes_per_batch".to_string(), Json::Num(hot.feat_storage_bytes));
        arm.insert("hot_hit_rate".to_string(), Json::Num(hot.hot_hit_rate));
        arm.insert("hot_rows_per_batch".to_string(), Json::Num(hot.feat_hot_rows));
        tiers.insert(codec.name().to_string(), Json::Obj(arm));
    }
    match merge_section(path, "tiered_storage", stamped(7, tiers)) {
        Ok(()) => println!("bench_coop: wrote section `tiered_storage` to {}", path.display()),
        Err(e) => eprintln!("bench_coop: could not write {}: {e}", path.display()),
    }

    // ---- communication-avoiding fabric: replication sweep --------------
    // The same 4-PE cooperative workload (f32, untiered, threaded) at
    // replica-group sizes r ∈ {1, 2, 4}: the feature-fabric total stays
    // put while its inter-group share drops with r (mirror serving keeps
    // same-group rows off the slow links). Alongside, the costmodel's
    // collective pick across payload sizes on flat and replicated
    // topologies — what `--allreduce auto` resolves to.
    pipe.set_codec(Codec::F32);
    pipe.set_hot_mb(0);
    let mut repl = BTreeMap::new();
    repl.insert("dataset".to_string(), Json::Str(ds_name.to_string()));
    repl.insert("pes".to_string(), Json::Num(4.0));
    repl.insert("smoke".to_string(), Json::Bool(smoke));
    for r in [1usize, 2, 4] {
        pipe.set_replication(r);
        let rep = pipe.engine_report();
        let auto = pipe.collective_for_grads();
        println!(
            "fabric/coop_4pe_{ds_name} r={r}: {:>8.1} KiB fabric/batch, {:>8.1} KiB \
             inter-group, auto all-reduce pick: {}",
            rep.feat_fabric_bytes / 1024.0,
            rep.feat_fabric_inter_bytes / 1024.0,
            auto.name()
        );
        let mut arm = BTreeMap::new();
        arm.insert("fabric_bytes_per_batch".to_string(), Json::Num(rep.feat_fabric_bytes));
        arm.insert(
            "fabric_inter_bytes_per_batch".to_string(),
            Json::Num(rep.feat_fabric_inter_bytes),
        );
        arm.insert("auto_collective".to_string(), Json::Str(auto.name().to_string()));
        repl.insert(format!("r{r}"), Json::Obj(arm));
    }
    pipe.set_replication(1);
    let mut picks = BTreeMap::new();
    for payload in [4u64 << 10, 256 << 10, 1 << 20, 16 << 20, 64 << 20] {
        let flat = pick_collective(payload, &Topology::flat(4), &FabricModel::default());
        let grouped = pick_collective(payload, &Topology::new(4, 2), &FabricModel::default());
        println!(
            "fabric/pick_collective {:>6} KiB: flat={} replicated_r2={}",
            payload >> 10,
            flat.name(),
            grouped.name()
        );
        let mut arm = BTreeMap::new();
        arm.insert("flat".to_string(), Json::Str(flat.name().to_string()));
        arm.insert("replicated_r2".to_string(), Json::Str(grouped.name().to_string()));
        picks.insert(format!("{}KiB", payload >> 10), Json::Obj(arm));
    }
    repl.insert("pick_collective".to_string(), Json::Obj(picks));
    match merge_section(path, "replication", stamped(8, repl)) {
        Ok(()) => println!("bench_coop: wrote section `replication` to {}", path.display()),
        Err(e) => eprintln!("bench_coop: could not write {}: {e}", path.display()),
    }
}
