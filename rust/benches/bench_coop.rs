//! Cooperative-engine benchmarks: Algorithm 1 sampling rounds, the
//! all-to-all fabric, and — the headline — the thread-per-PE engine vs
//! the serial reference, demonstrating real concurrency: with 4 PEs the
//! cooperative batch wall-clock sits well below the summed per-PE stage
//! times (`cargo bench --bench bench_coop`; `-- --test` runs the smoke
//! configuration CI uploads as the perf-trajectory artifact). The engine
//! comparison is constructed through `pipeline::PipelineBuilder`, like
//! every other entry stack.

use coopgnn::coop::all_to_all::Exchange;
use coopgnn::coop::coop_sampler::{partition_seeds, sample_cooperative};
use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::coop::indep::sample_independent;
use coopgnn::graph::{generate, partition};
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::sampling::{SamplerConfig, SamplerKind};
use coopgnn::util::rng::Pcg64;
use coopgnn::util::stats::{bench_ms, smoke_mode, Timer};

fn main() {
    let smoke = smoke_mode();
    let (nv, deg, n_seeds, warmup, iters) =
        if smoke { (20_000, 8.0, 1024u32, 1, 4) } else { (89_200, 10.1, 4096, 2, 15) };
    let g = generate::chung_lu(nv, deg, 2.5, 1);
    let part = partition::random(&g, 4, 2);
    let cfg = SamplerConfig::default();
    let seeds: Vec<u32> = (0..n_seeds).map(|i| i * 19 % nv as u32).collect();
    let per_pe = partition_seeds(&seeds, &part);

    bench_ms("coop_sample/4pe_labor0_serial_ref", warmup, iters, || {
        let mut samplers: Vec<_> =
            (0..4).map(|_| cfg.build(SamplerKind::Labor0, &g, 7)).collect();
        let c = sample_cooperative(&g, &part, &mut samplers, &per_pe, 3);
        std::hint::black_box(&c);
    });

    bench_ms("indep_sample/4pe_labor0", warmup, iters, || {
        let mut samplers: Vec<_> =
            (0..4).map(|p| cfg.build(SamplerKind::Labor0, &g, 7 + p)).collect();
        let s = sample_independent(&mut samplers, &per_pe);
        std::hint::black_box(&s);
    });

    // raw all-to-all routing throughput (serial reference fabric)
    let mut rng = Pcg64::new(3);
    let bucket_len = if smoke { 2_000 } else { 20_000 };
    let buckets: Vec<Vec<Vec<u32>>> = (0..8)
        .map(|_| {
            (0..8)
                .map(|_| (0..bucket_len).map(|_| rng.next_u64() as u32).collect())
                .collect()
        })
        .collect();
    let items: usize = buckets.iter().flatten().map(|b| b.len()).sum();
    let s = bench_ms("all_to_all/8pe_route", warmup, iters, || {
        let mut ex = Exchange::new(8);
        let inboxes = ex.route(&buckets, 4);
        std::hint::black_box(&inboxes);
    });
    println!("  -> {:.1} M ids/s routed", items as f64 / (s.p50 / 1e3) / 1e6);

    // ---- thread-per-PE engine vs serial reference ----------------------
    // The acceptance demonstration: with num_pes = 4 the cooperative
    // engine runs PEs concurrently. The honest evidence is the serial
    // reference doing *identical work* single-threaded: threaded batch
    // wall-clock must drop below serial batch wall-clock. (Per-PE stage
    // times are also printed, but in threaded mode they include exchange
    // waits, so their sum exceeding the wall is necessary, not
    // sufficient, for real overlap.) Registry dataset so the numbers
    // track a real workload shape across PRs. One PipelineBuilder call
    // stands up the workload; only `cfg.exec` is toggled between runs.
    let (ds_name, b, measure) = if smoke { ("tiny", 128, 3) } else { ("flickr-s", 1024, 8) };
    let mut pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .mode(Mode::Cooperative)
        .num_pes(4)
        .batch_per_pe(b)
        .warmup_batches(1)
        .measure_batches(measure)
        .seed(7)
        .build()
        .expect("registry dataset");
    pipe.cfg.cache_per_pe = Some((pipe.ds.cache_size / 4).max(64));
    let mut batch_walls: Vec<f64> = Vec::new();
    for exec in [ExecMode::Serial, ExecMode::Threaded] {
        pipe.cfg.exec = exec;
        let t = Timer::start();
        let r = pipe.engine_report();
        let total_ms = t.elapsed_ms();
        batch_walls.push(r.wall_batch_ms);
        println!(
            "engine/coop_4pe_{ds_name} exec={:<8} total {:>8.1} ms | per batch: wall {:>7.2} ms, \
             per-PE stage sum {:>7.2} ms (sampling {:.2} + feature {:.2}; incl. exchange waits)",
            exec.name(),
            total_ms,
            r.wall_batch_ms,
            r.wall_sampling_ms + r.wall_feature_ms,
            r.wall_sampling_ms,
            r.wall_feature_ms,
        );
    }
    let (serial_wall, threaded_wall) = (batch_walls[0], batch_walls[1]);
    let speedup = if threaded_wall > 0.0 { serial_wall / threaded_wall } else { 0.0 };
    println!(
        "engine/coop_4pe_{ds_name} parallelism check: serial {serial_wall:.2} ms/batch vs \
         threaded {threaded_wall:.2} ms/batch -> {speedup:.2}x: {}",
        if speedup > 1.1 {
            "CONCURRENT (threaded beats the identical-work serial reference)"
        } else {
            "WARNING: no speedup over serial (single-core runner or batch too small?)"
        }
    );
}
