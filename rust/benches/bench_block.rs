//! MFG construction + fixed-fanout padding benchmarks — the per-batch
//! block-building hot path between sampling and PJRT execution.

use coopgnn::graph::generate;
use coopgnn::sampling::{block, SamplerConfig, SamplerKind};
use coopgnn::util::stats::{bench_ms, smoke_mode};

fn main() {
    let smoke = smoke_mode();
    let nv: usize = if smoke { 20_000 } else { 222_000 };
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 20) };
    let g = generate::chung_lu(nv, 29.1, 2.4, 1).to_undirected();
    let seeds: Vec<u32> = (0..1024u32).map(|i| i * 217 % nv as u32).collect();
    let cfg = SamplerConfig::default();

    let mut s = cfg.build(SamplerKind::Labor0, &g, 7);
    let mut mfg = s.sample_mfg(&seeds);
    println!("papers-s-sized MFG: counts {:?}", mfg.vertex_counts());

    bench_ms("build_mfg/labor0_b1024", warmup, iters, || {
        mfg = s.sample_mfg(&seeds);
        s.advance_batch();
    });

    let counts = mfg.vertex_counts();
    let caps = block::ShapeCaps { k: 40, n: counts.iter().map(|c| c + c / 4 + 8).collect() };
    bench_ms("pad/measured_caps", warmup, iters, || {
        let pb = mfg.pad(&caps, |_| 3);
        std::hint::black_box(&pb);
    });

    // merged (indep-mode) construction
    let parts: Vec<_> = (0..4)
        .map(|i| {
            let mut si = cfg.build(SamplerKind::Labor0, &g, 100 + i);
            si.sample_mfg(&seeds[..256])
        })
        .collect();
    bench_ms("merge_mfgs/4x256", warmup, iters, || {
        let m = block::merge_mfgs(&parts);
        std::hint::black_box(&m);
    });
}
