//! One bench entry per paper table/figure family: runs each repro
//! harness in quick mode and reports its wall time, so `cargo bench`
//! exercises every generator the paper's evaluation needs (Figures 3/5/9,
//! Tables 3–7, scaling note).

use coopgnn::repro::{self, Ctx};
use coopgnn::util::stats::{smoke_mode, Timer};
use std::path::Path;

fn main() {
    let smoke = smoke_mode();
    let out = std::env::temp_dir().join("coopgnn_bench_tables");
    let have_artifacts = Path::new("artifacts/manifest.json").exists();
    let ctx = Ctx {
        out: out.clone(),
        quick: true,
        seed: 0xBE7C,
        artifacts: "artifacts".into(),
        ..Default::default()
    };
    let mut ids: Vec<&str> = if smoke {
        vec!["table7", "scaling"]
    } else {
        vec!["fig3", "fig5a", "fig5b", "table4", "table7", "scaling"]
    };
    if have_artifacts {
        ids.push("table3");
        ids.push("fig9");
    } else {
        println!("(artifacts/ missing: skipping table3/fig9 training benches)");
    }
    for id in ids {
        let t = Timer::start();
        repro::run(id, &ctx).unwrap();
        println!("bench repro/{id:<8} (quick) {:>10.1} ms", t.elapsed_ms());
    }
    std::fs::remove_dir_all(&out).ok();
}
