//! Train-step path benchmarks.
//!
//! Part 1 (always runs): the multi-PE sampling front half of a training
//! step — the block-diagonal merged MFG of P independent sub-batches —
//! serial vs one-thread-per-PE, driving `pipeline::sample_indep_parts`
//! (the `Batching::IndepMerged` core) plus the full
//! `pipeline::TrainStream` through the `MinibatchStream` seam, exactly
//! what `Trainer` consumes.
//!
//! Part 2 (needs `make artifacts` + a PJRT-enabled build): end-to-end
//! train-step latency through the runtime with the per-batch breakdown
//! (sample / pad / feature / execute). Skips cleanly otherwise.

use coopgnn::coop::engine::ExecMode;
use coopgnn::pipeline::{
    sample_indep_parts, Batching, MinibatchStream, PipelineBuilder, TrainStream,
};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{block, SamplerConfig, SamplerKind};
use coopgnn::train::Trainer;
use coopgnn::util::stats::{bench_ms, smoke_mode, Summary};
use std::path::Path;

fn main() {
    let smoke = smoke_mode();

    // ---- part 1: merged-MFG sampling, serial vs thread-per-PE ----------
    let (ds_name, batch, warmup, iters) =
        if smoke { ("tiny", 128usize, 1, 4) } else { ("conv", 1024, 2, 12) };
    let pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .seed(1)
        .build()
        .expect("registry dataset");
    let cfg = SamplerConfig::default();
    let p = 4usize;
    let seeds: Vec<u32> = pipe.ds.train.iter().take(batch).copied().collect();

    for exec in [ExecMode::Serial, ExecMode::Threaded] {
        bench_ms(&format!("merged_mfg/{ds_name}_4pe_{}", exec.name()), warmup, iters, || {
            let parts = sample_indep_parts(
                &pipe.ds.graph,
                cfg,
                SamplerKind::Labor0,
                &seeds,
                p,
                99,
                exec,
            );
            let m = block::merge_mfgs(&parts);
            std::hint::black_box(&m);
        });
    }

    // the same front half through the stream seam the Trainer pulls from
    // (seed drawing + per-step re-seeded sub-batches + merge)
    let mut stream = TrainStream::new(
        &pipe.ds,
        SamplerKind::Labor0,
        cfg,
        batch,
        99,
        ExecMode::Threaded,
        Batching::IndepMerged { pes: p },
    );
    bench_ms(&format!("merged_mfg/{ds_name}_4pe_stream"), warmup, iters, || {
        let mb = stream.next_batch();
        std::hint::black_box(&mb);
    });

    // ---- part 2: PJRT train-step latency (artifact-gated) --------------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_train_step: artifacts/ missing (run `make artifacts`); skipping PJRT part");
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("bench_train_step: {e}; skipping PJRT part");
            return;
        }
    };
    let manifest = Manifest::load(dir).unwrap();
    for (ds_name, config, iters) in
        [("tiny", "tiny-b32", 40usize), ("conv", "conv-b256", 15)]
    {
        let tpipe = PipelineBuilder::new().dataset(ds_name).seed(1).build().unwrap();
        let opts = tpipe.trainer_options();
        let mut t = Trainer::new(&rt, &manifest, config, &tpipe.ds, &opts).unwrap();
        // warmup
        for _ in 0..3 {
            t.step().unwrap();
        }
        let (mut samp, mut pad, mut feat, mut exec, mut total) =
            (vec![], vec![], vec![], vec![], vec![]);
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            let s = t.step().unwrap();
            total.push(t0.elapsed().as_secs_f64() * 1e3);
            samp.push(s.sample_ms);
            pad.push(s.pad_ms);
            feat.push(s.feature_ms);
            exec.push(s.exec_ms);
        }
        println!("train_step/{config}:");
        println!("  sample  {}", Summary::of(&samp));
        println!("  pad     {}", Summary::of(&pad));
        println!("  feature {}", Summary::of(&feat));
        println!("  execute {}", Summary::of(&exec));
        println!("  total   {}", Summary::of(&total));
    }
}
