//! End-to-end train-step latency through PJRT (L2/L1 execution from the
//! L3 hot path) for the tiny and conv artifact configs: the per-batch
//! breakdown (sample / pad / feature / execute) that the perf pass
//! optimizes. Skips cleanly when artifacts are absent.

use coopgnn::graph::datasets;
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::train::{Trainer, TrainerOptions};
use coopgnn::util::stats::Summary;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_train_step: artifacts/ missing (run `make artifacts`); skipping");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    for (ds_name, config, iters) in
        [("tiny", "tiny-b32", 40usize), ("conv", "conv-b256", 15)]
    {
        let ds = datasets::build(ds_name, 1).unwrap();
        let opts = TrainerOptions::default();
        let mut t = Trainer::new(&rt, &manifest, config, &ds, &opts).unwrap();
        // warmup
        for _ in 0..3 {
            t.step().unwrap();
        }
        let (mut samp, mut pad, mut feat, mut exec, mut total) =
            (vec![], vec![], vec![], vec![], vec![]);
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            let s = t.step().unwrap();
            total.push(t0.elapsed().as_secs_f64() * 1e3);
            samp.push(s.sample_ms);
            pad.push(s.pad_ms);
            feat.push(s.feature_ms);
            exec.push(s.exec_ms);
        }
        println!("train_step/{config}:");
        println!("  sample  {}", Summary::of(&samp));
        println!("  pad     {}", Summary::of(&pad));
        println!("  feature {}", Summary::of(&feat));
        println!("  execute {}", Summary::of(&exec));
        println!("  total   {}", Summary::of(&total));
    }
}
