//! Train-step path benchmarks.
//!
//! Part 1 (always runs): the multi-PE sampling front half of a training
//! step — the block-diagonal merged MFG of P independent sub-batches —
//! serial vs one-thread-per-PE, driving `pipeline::sample_indep_parts`
//! (the `Batching::IndepMerged` core) plus the full
//! `pipeline::TrainStream` through the `MinibatchStream` seam, exactly
//! what `Trainer` consumes.
//!
//! Part 1.5 (always runs): **prefetch overlap** — the threaded 4-PE
//! `TrainStream` (sampling + real feature gathering) driven `--prefetch`
//! off vs on against a deterministic compute stand-in that sweeps the
//! gathered feature buffer (the PJRT runtime is stubbed in this build,
//! so the stand-in models the execution half's cost). With prefetch the
//! producer samples + gathers batch t+1 while the consumer sweeps batch
//! t, so per-step wall approaches max(produce, consume) instead of
//! their sum; checksums assert the batches are bit-identical either
//! way. Results land in `BENCH_pipeline.json` (section
//! `bench_train_step`) for the CI perf-trajectory artifact.
//!
//! Part 1.75 (always runs): the **multi-PE training plane** — 4 trainer
//! replicas of the layered GNN over the engine stream (independent vs
//! cooperative minibatching) with the per-layer activation exchange and
//! the fabric gradient all-reduce, asserting replica lockstep and
//! recording ms/step + storage/fabric/activation/gradient bytes per
//! step into the `multi_pe_train` field of the JSON section, plus the
//! per-layer gather/matmul compute decomposition into `layered_train`
//! (`repro end2end` is the full multi-PE-count table).
//!
//! Part 2 (needs `make artifacts` + a PJRT-enabled build): end-to-end
//! train-step latency through the runtime, prefetch off vs on, with the
//! per-batch breakdown (sample / pad / feature / execute). Skips
//! cleanly otherwise.

// Benches are timing harnesses (coopgnn-lint allowlists rust/benches/).
#![allow(clippy::disallowed_methods)]

use coopgnn::coop::all_to_all::AllReduceStrategy;
use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::pipeline::{
    sample_indep_parts, with_prefetch, Batching, MinibatchStream, PipelineBuilder,
    PrefetchedStream, TrainStream,
};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{block, SamplerConfig, SamplerKind};
use coopgnn::train::Trainer;
use coopgnn::util::json::{merge_section, stamped, Json};
use coopgnn::util::stats::{bench_ms, smoke_mode, Summary, Timer};
use std::collections::BTreeMap;
use std::path::Path;

/// Deterministic stand-in for the train-step compute: `passes` scaled
/// sweeps over the gathered feature buffer. Returns a checksum so the
/// prefetch-on/off runs can assert bit-identical batch content.
fn consume_features(features: &[f32], passes: usize) -> f64 {
    let mut acc = 0f64;
    for p in 0..passes {
        let scale = 1.0 + p as f64 * 1e-6;
        let mut pass = 0f64;
        for &x in features {
            pass += x as f64;
        }
        acc += pass * scale;
    }
    acc
}

fn main() {
    let smoke = smoke_mode();

    // ---- part 1: merged-MFG sampling, serial vs thread-per-PE ----------
    let (ds_name, batch, warmup, iters) =
        if smoke { ("tiny", 128usize, 1, 4) } else { ("conv", 1024, 2, 12) };
    let pipe = PipelineBuilder::new()
        .dataset(ds_name)
        .seed(1)
        .build()
        .expect("registry dataset");
    let cfg = SamplerConfig::default();
    let p = 4usize;
    let seeds: Vec<u32> = pipe.ds.train.iter().take(batch).copied().collect();

    for exec in [ExecMode::Serial, ExecMode::Threaded] {
        bench_ms(&format!("merged_mfg/{ds_name}_4pe_{}", exec.name()), warmup, iters, || {
            let parts = sample_indep_parts(
                &pipe.ds.graph,
                cfg,
                SamplerKind::Labor0,
                &seeds,
                p,
                99,
                exec,
            );
            let m = block::merge_mfgs(&parts);
            std::hint::black_box(&m);
        });
    }

    // the same front half through the stream seam the Trainer pulls from
    // (seed drawing + per-step re-seeded sub-batches + merge + gather)
    let mut stream = TrainStream::new(
        &pipe.ds,
        SamplerKind::Labor0,
        cfg,
        batch,
        99,
        ExecMode::Threaded,
        Batching::IndepMerged { pes: p },
    );
    bench_ms(&format!("merged_mfg/{ds_name}_4pe_stream"), warmup, iters, || {
        let mb = stream.next_batch();
        std::hint::black_box(&mb);
    });

    // ---- part 1.5: prefetch overlap on the threaded TrainStream --------
    let (steps, passes) = if smoke { (6usize, 4usize) } else { (16, 8) };
    let mk_stream = || {
        TrainStream::new(
            &pipe.ds,
            SamplerKind::Labor0,
            cfg,
            batch,
            4242,
            ExecMode::Threaded,
            Batching::IndepMerged { pes: p },
        )
    };
    fn drive(
        s: &mut dyn MinibatchStream,
        steps: usize,
        passes: usize,
        sums: &mut Vec<f64>,
        storage: &mut u64,
    ) {
        for _ in 0..steps {
            let mb = s.next_batch();
            let w = &mb.per_pe[0];
            *storage += w.bytes_from_storage;
            let feats = w.features.as_ref().expect("train stream gathers features");
            sums.push(consume_features(feats, passes));
        }
    }
    let mut walls = Vec::new();
    let mut checksums = Vec::new();
    let mut bytes_per_batch = 0f64;
    for prefetch in [false, true] {
        let mut step_checksums: Vec<f64> = Vec::with_capacity(steps);
        let mut storage_bytes = 0u64;
        let t = Timer::start();
        if prefetch {
            with_prefetch(mk_stream(), |s| {
                drive(s, steps, passes, &mut step_checksums, &mut storage_bytes)
            });
        } else {
            let mut s = mk_stream();
            drive(&mut s, steps, passes, &mut step_checksums, &mut storage_bytes);
        }
        let per_step = t.elapsed_ms() / steps as f64;
        println!(
            "train_stream/{ds_name}_4pe prefetch={} {:>8.2} ms/step \
             ({:.1} KiB gathered/step, {passes} consumer passes)",
            prefetch as u8,
            per_step,
            storage_bytes as f64 / steps as f64 / 1024.0,
        );
        bytes_per_batch = storage_bytes as f64 / steps as f64;
        walls.push(per_step);
        checksums.push(step_checksums);
    }
    assert_eq!(
        checksums[0], checksums[1],
        "prefetch must not change batch content (checksum mismatch)"
    );
    let overlap_speedup = if walls[1] > 0.0 { walls[0] / walls[1] } else { 0.0 };
    println!(
        "train_stream/{ds_name}_4pe prefetch overlap: {:.2} -> {:.2} ms/step = {:.2}x \
         (identical checksums): {}",
        walls[0],
        walls[1],
        overlap_speedup,
        if overlap_speedup > 1.05 {
            "OVERLAPPED (producer gathers batch t+1 during batch t's compute)"
        } else {
            "WARNING: no overlap gain (single-core runner or consumer too cheap?)"
        }
    );

    // ---- part 1.75: multi-PE training plane, indep vs coop -------------
    // The end-to-end arm: per-PE trainer replicas over the engine stream
    // with the fabric gradient all-reduce (`repro end2end` is the full
    // table; this keeps one comparison point in the perf trajectory).
    let (mp_batch, mp_steps) = if smoke { (64usize, 4usize) } else { (512, 10) };
    let mp_pes = 4usize;
    let mut multi = BTreeMap::new();
    multi.insert("pes".to_string(), Json::Num(mp_pes as f64));
    multi.insert("batch_per_pe".to_string(), Json::Num(mp_batch as f64));
    multi.insert("steps".to_string(), Json::Num(mp_steps as f64));
    let mut mode_ms = Vec::new();
    let mut layered = BTreeMap::new();
    for mode in [Mode::Independent, Mode::Cooperative] {
        let mpipe = PipelineBuilder::new()
            .dataset(ds_name)
            .mode(mode)
            .num_pes(mp_pes)
            .batch_per_pe(mp_batch)
            .seed(1)
            .build()
            .expect("registry dataset");
        let mut stream = mpipe.stream();
        let mut trainer = mpipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        let rep = trainer.run(&mut stream, mp_steps, &mpipe.ds.labels);
        assert!(
            trainer.replicas_in_lockstep(),
            "bench: {mp_pes}-PE replicas must stay bit-identical"
        );
        println!(
            "parallel_train/{ds_name}_{}pe_{} {:>8.2} ms/step (compute {:.2}, all-reduce {:.2}; \
             {:.1} KiB storage + {:.1} KiB feat fabric + {:.1} KiB acts + {:.1} KiB grads \
             per step)",
            mp_pes,
            mode.name(),
            rep.ms_per_step,
            rep.compute_ms,
            rep.allreduce_ms,
            rep.storage_bytes_per_step / 1024.0,
            rep.fabric_bytes_per_step / 1024.0,
            rep.act_bytes_per_step / 1024.0,
            rep.grad_bytes_per_step / 1024.0,
        );
        let mut arm = BTreeMap::new();
        arm.insert("ms_per_step".to_string(), Json::Num(rep.ms_per_step));
        arm.insert("compute_ms".to_string(), Json::Num(rep.compute_ms));
        arm.insert("allreduce_ms".to_string(), Json::Num(rep.allreduce_ms));
        arm.insert("storage_bytes_per_step".to_string(), Json::Num(rep.storage_bytes_per_step));
        arm.insert("fabric_bytes_per_step".to_string(), Json::Num(rep.fabric_bytes_per_step));
        arm.insert("act_bytes_per_step".to_string(), Json::Num(rep.act_bytes_per_step));
        arm.insert("grad_bytes_per_step".to_string(), Json::Num(rep.grad_bytes_per_step));
        multi.insert(mode.name().to_lowercase(), Json::Obj(arm));
        mode_ms.push(rep.ms_per_step);

        // per-layer compute decomposition of the layered model: summed
        // gather-aggregate and matmul ms over every PE and step
        // (index 0 = output layer, matching ModelDims level order)
        let prof = trainer.layer_profile();
        let per_step = |v: &[f64]| {
            Json::Arr(v.iter().map(|&ms| Json::Num(ms / mp_steps as f64)).collect())
        };
        let dims = trainer.dims();
        layered.insert("layers".to_string(), Json::Num(dims.layers as f64));
        layered.insert("hidden".to_string(), Json::Num(dims.hidden as f64));
        let key = mode.name().to_lowercase();
        layered.insert(format!("{key}_gather_ms_per_step"), per_step(&prof.gather_ms));
        layered.insert(format!("{key}_matmul_ms_per_step"), per_step(&prof.matmul_ms));
        println!(
            "layered_train/{ds_name}_{mp_pes}pe_{} L={} h={}: gather {:?} + matmul {:?} \
             ms/step by layer (0 = output)",
            mode.name(),
            dims.layers,
            dims.hidden,
            prof.gather_ms.iter().map(|m| (m / mp_steps as f64 * 100.0).round() / 100.0)
                .collect::<Vec<_>>(),
            prof.matmul_ms.iter().map(|m| (m / mp_steps as f64 * 100.0).round() / 100.0)
                .collect::<Vec<_>>(),
        );
    }
    let coop_speedup = if mode_ms[1] > 0.0 { mode_ms[0] / mode_ms[1] } else { 0.0 };
    multi.insert("coop_speedup_vs_indep".to_string(), Json::Num(coop_speedup));
    println!(
        "parallel_train/{ds_name}_{mp_pes}pe coop-vs-indep end-to-end: {:.2} / {:.2} ms/step = \
         {coop_speedup:.2}x",
        mode_ms[0], mode_ms[1]
    );

    let mut section = BTreeMap::new();
    section.insert("dataset".to_string(), Json::Str(ds_name.to_string()));
    section.insert("pes".to_string(), Json::Num(p as f64));
    section.insert("global_batch".to_string(), Json::Num(batch as f64));
    section.insert("smoke".to_string(), Json::Bool(smoke));
    section.insert("prefetch0_ms_per_step".to_string(), Json::Num(walls[0]));
    section.insert("prefetch1_ms_per_step".to_string(), Json::Num(walls[1]));
    section.insert("prefetch_speedup".to_string(), Json::Num(overlap_speedup));
    section.insert("storage_bytes_per_batch".to_string(), Json::Num(bytes_per_batch));
    section.insert("fabric_bytes_per_batch".to_string(), Json::Num(0.0));
    section.insert("checksums_identical".to_string(), Json::Bool(true));
    section.insert("multi_pe_train".to_string(), Json::Obj(multi));
    section.insert("layered_train".to_string(), Json::Obj(layered));
    let json_path = Path::new("BENCH_pipeline.json");
    // stamped: schema_version + the builder seed recipe (all arms above
    // build with seed 1), closing the "artifacts silently became
    // incomparable when seed derivation changed" caveat
    match merge_section(json_path, "bench_train_step", stamped(1, section)) {
        Ok(()) => {
            println!("bench_train_step: wrote section `bench_train_step` to {}",
                json_path.display())
        }
        Err(e) => eprintln!("bench_train_step: could not write {}: {e}", json_path.display()),
    }

    // ---- part 2: PJRT train-step latency (artifact-gated) --------------
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_train_step: artifacts/ missing (run `make artifacts`); skipping PJRT part");
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("bench_train_step: {e}; skipping PJRT part");
            return;
        }
    };
    let manifest = Manifest::load(dir).unwrap();
    for (ds_name, config, iters) in
        [("tiny", "tiny-b32", 40usize), ("conv", "conv-b256", 15)]
    {
        let tpipe = PipelineBuilder::new().dataset(ds_name).seed(1).build().unwrap();
        let opts = tpipe.trainer_options();
        for prefetch in [false, true] {
            let mut t = Trainer::new(&rt, &manifest, config, &tpipe.ds, &opts).unwrap();
            let (mut samp, mut pad, mut feat, mut exec, mut total) =
                (vec![], vec![], vec![], vec![], vec![]);
            let mut losses: Vec<f32> = Vec::new();
            {
                let mut one_step = |t: &mut Trainer,
                                    s: Option<&mut PrefetchedStream>,
                                    record: bool| {
                    let t0 = std::time::Instant::now();
                    let st = match s {
                        Some(stream) => t.step_from(stream).unwrap(),
                        None => t.step().unwrap(),
                    };
                    if record {
                        total.push(t0.elapsed().as_secs_f64() * 1e3);
                        samp.push(st.sample_ms);
                        pad.push(st.pad_ms);
                        feat.push(st.feature_ms);
                        exec.push(st.exec_ms);
                        losses.push(st.loss);
                    }
                };
                if prefetch {
                    // the trainer's own recipe, shared store — no second
                    // materialization, no drift
                    let stream = t.make_stream();
                    with_prefetch(stream, |s| {
                        for i in 0..(3 + iters) {
                            one_step(&mut t, Some(&mut *s), i >= 3);
                        }
                    });
                } else {
                    for i in 0..(3 + iters) {
                        one_step(&mut t, None, i >= 3);
                    }
                }
            }
            println!("train_step/{config} prefetch={}:", prefetch as u8);
            println!("  sample  {}", Summary::of(&samp));
            println!("  pad     {}", Summary::of(&pad));
            println!("  feature {}", Summary::of(&feat));
            println!("  execute {}", Summary::of(&exec));
            println!("  total   {}", Summary::of(&total));
            println!("  final loss {:.5}", losses.last().copied().unwrap_or(f32::NAN));
        }
    }
}
