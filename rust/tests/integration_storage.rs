//! Storage-plane integration gates: the tiered compressed feature store
//! must be invisible at f32 defaults (bit-identical to the legacy
//! single-tier store — the PR-6 regression pin), report the hot/cold
//! byte split faithfully when tiered, and keep the costmodel-driven
//! prefetcher inside its budget without perturbing any count.

use coopgnn::coop::engine::{EngineConfig, Mode};
use coopgnn::feature::{Codec, FeatureStore, TieredStore};
use coopgnn::graph::{datasets, partition};
use coopgnn::pipeline::{EngineStream, MinibatchStream, PipelineBuilder};
use std::sync::Arc;

fn bits(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// The f32 regression pin: a `TieredStore` at codec f32 / hot budget 0
/// produces batches bit-identical to the PR-6 `PartitionedFeatureStore`
/// path — same counts, same byte ledger, same feature payload bits —
/// across modes and PE counts at a fixed seed.
#[test]
fn f32_tiered_store_is_bit_identical_to_the_legacy_store() {
    let ds = datasets::build("tiny", 42).unwrap();
    for (pes, mode) in [
        (1, Mode::Independent),
        (3, Mode::Independent),
        (3, Mode::Cooperative),
    ] {
        let part = partition::random(&ds.graph, pes, 9);
        let cfg = EngineConfig {
            mode,
            num_pes: pes,
            batch_per_pe: 24,
            cache_per_pe: 200,
            warmup_batches: 0,
            measure_batches: 4,
            seed: 0xC0FFEE,
            ..Default::default()
        };
        let mut legacy = EngineStream::new(&ds, &part, &cfg);
        let store: Arc<dyn FeatureStore> =
            Arc::new(TieredStore::build(&ds, &part, Codec::F32, 0));
        let mut tiered = EngineStream::with_store(&ds, &part, &cfg, store);
        for batch in 0..4 {
            let a = legacy.next_batch();
            let b = tiered.next_batch();
            for (pe, (x, y)) in a.per_pe.iter().zip(&b.per_pe).enumerate() {
                let ctx = format!("{mode:?} P={pes} batch {batch} PE {pe}");
                assert_eq!(x.requested, y.requested, "{ctx}: requested");
                assert_eq!(x.misses, y.misses, "{ctx}: misses");
                assert_eq!(x.fabric, y.fabric, "{ctx}: fabric rows");
                assert_eq!(x.row_bytes, y.row_bytes, "{ctx}: row_bytes");
                assert_eq!(x.bytes_from_storage, y.bytes_from_storage, "{ctx}: β bytes");
                assert_eq!(x.fabric_bytes, y.fabric_bytes, "{ctx}: α bytes");
                assert_eq!(x.hot_rows, y.hot_rows, "{ctx}: hot fills");
                assert_eq!(x.hot_bytes, 0, "{ctx}: hot budget 0 must stay untiered");
                assert_eq!(x.feature_vertices, y.feature_vertices, "{ctx}: vertex lists");
                let (fx, fy) = (x.features.as_ref().unwrap(), y.features.as_ref().unwrap());
                assert_eq!(bits(fx), bits(fy), "{ctx}: feature payload bits");
            }
        }
    }
}

/// A full default-config engine report survives a codec round trip: run
/// at f32 defaults, re-run over int8 + hot tier, switch back, and the
/// third report equals the first field-for-field (wall clocks excepted)
/// — `set_codec`/`set_hot_mb` rebuild the store cleanly and the default
/// path carries no tiered residue.
#[test]
fn default_f32_report_survives_a_codec_round_trip() {
    let zeroed = |mut r: coopgnn::coop::engine::EngineReport| {
        r.wall_sampling_ms = 0.0;
        r.wall_feature_ms = 0.0;
        r.wall_batch_ms = 0.0;
        format!("{r:?}")
    };
    let mut pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .num_pes(2)
        .batch_per_pe(32)
        .cache_per_pe(256)
        .warmup_batches(1)
        .measure_batches(3)
        .build()
        .unwrap();
    let before = zeroed(pipe.engine_report());
    pipe.set_codec(Codec::Int8);
    pipe.set_hot_mb(1);
    let compressed = pipe.engine_report();
    assert_eq!(pipe.feature_store().row_bytes(), pipe.ds.feat_dim + 5);
    assert!(compressed.feat_hot_rows > 0.0, "1 MiB of dim-16 rows must tier tiny hot");
    pipe.set_codec(Codec::F32);
    pipe.set_hot_mb(0);
    let after = zeroed(pipe.engine_report());
    assert_eq!(before, after, "f32 default report must survive the codec round trip");
}

/// Tiering moves bytes between ledgers, never counts: with a hot tier
/// covering all of tiny, every fill is served from PE memory (γ), the
/// storage ledger (β) drops to zero, the hit rate saturates, and the
/// count plane matches the untiered run exactly.
#[test]
fn hot_tier_absorbs_fills_and_reports_the_split() {
    let mut pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Independent)
        .num_pes(1)
        .batch_per_pe(64)
        .cache_per_pe(400)
        .warmup_batches(1)
        .measure_batches(4)
        .codec(Codec::Int8)
        .hot_mb(1)
        .build()
        .unwrap();
    let hot = pipe.engine_report();
    assert!(hot.feat_misses > 0.0, "the cache must miss for tiers to matter");
    assert!(hot.feat_hot_rows > 0.0);
    assert_eq!(hot.feat_storage_bytes, 0.0, "a fully-hot store pulls nothing cold");
    let decoded = (pipe.ds.feat_dim * 4) as f64;
    assert!(
        (hot.feat_hot_bytes - hot.feat_hot_rows * decoded).abs() < 1e-6,
        "hot fills are charged decoded bytes"
    );
    assert!((hot.hot_hit_rate - 1.0).abs() < 1e-12, "every fill was hot");
    assert!(hot.derived_miss_rate <= hot.cache_miss_rate);
    pipe.set_hot_mb(0);
    let cold = pipe.engine_report();
    assert_eq!(cold.feat_misses, hot.feat_misses, "counts never move with tiering");
    assert_eq!(cold.feat_requested, hot.feat_requested);
    assert_eq!(cold.feat_hot_rows, 0.0);
    assert_eq!(cold.hot_hit_rate, 0.0);
    let wire = (pipe.ds.feat_dim + 5) as f64;
    assert!(
        (cold.feat_storage_bytes - cold.feat_misses * wire).abs() < 1e-6,
        "untiered int8 charges every miss the encoded wire size"
    );
}

/// The costmodel-driven prefetch seam: with a small hot tier, each
/// `next_batch` promotes the exactly-predicted next seed draw into the
/// annex within the cold-bandwidth budget — and nothing about the
/// sampled counts or the feature payload moves.
#[test]
fn tiered_prefetch_promotes_within_budget_without_touching_counts() {
    let ds = datasets::build("tiny", 42).unwrap();
    let part = partition::random(&ds.graph, 2, 9);
    let hot_bytes = 64 * ds.feat_dim * 4; // 64 decoded rows: most of tiny stays cold
    let mk_cfg = |prefetch: bool| EngineConfig {
        mode: Mode::Cooperative,
        num_pes: 2,
        batch_per_pe: 24,
        cache_per_pe: 200,
        warmup_batches: 0,
        measure_batches: 3,
        seed: 7,
        prefetch,
        ..Default::default()
    };
    let store_on: Arc<dyn FeatureStore> =
        Arc::new(TieredStore::build(&ds, &part, Codec::Int8, hot_bytes));
    let budget = coopgnn::costmodel::default_prefetch_row_budget(store_on.row_bytes()) as u64;
    let mut on = EngineStream::with_store(&ds, &part, &mk_cfg(true), store_on);
    let store_off: Arc<dyn FeatureStore> =
        Arc::new(TieredStore::build(&ds, &part, Codec::Int8, hot_bytes));
    let mut off = EngineStream::with_store(&ds, &part, &mk_cfg(false), store_off);
    let mut promoted = 0u64;
    for batch in 0..3 {
        let a = on.next_batch();
        let b = off.next_batch();
        for (pe, (x, y)) in a.per_pe.iter().zip(&b.per_pe).enumerate() {
            let ctx = format!("batch {batch} PE {pe}");
            assert!(x.prefetch_rows <= budget, "{ctx}: budget overrun");
            assert_eq!(
                x.prefetch_bytes,
                x.prefetch_rows * x.row_bytes,
                "{ctx}: prefetch pulls wire bytes"
            );
            promoted += x.prefetch_rows;
            assert_eq!(y.prefetch_rows, 0, "{ctx}: prefetch off promotes nothing");
            // the count plane and the payload are prefetch-invariant;
            // only the hot/cold byte attribution may shift
            assert_eq!(x.requested, y.requested, "{ctx}: requested");
            assert_eq!(x.misses, y.misses, "{ctx}: misses");
            assert_eq!(x.fabric, y.fabric, "{ctx}: fabric rows");
            assert_eq!(x.feature_vertices, y.feature_vertices, "{ctx}: vertex lists");
            let (fx, fy) = (x.features.as_ref().unwrap(), y.features.as_ref().unwrap());
            assert_eq!(bits(fx), bits(fy), "{ctx}: payload bits");
            assert_eq!(
                x.bytes_from_storage + x.hot_rows * x.row_bytes,
                y.bytes_from_storage + y.hot_rows * y.row_bytes,
                "{ctx}: total fill wire-bytes conserved across attribution"
            );
        }
    }
    assert!(promoted > 0, "a mostly-cold store must see prefetch promotions");
}
