//! Pipeline API equivalence: the redesigned construction path
//! (`PipelineBuilder` / `MinibatchStream`) must reproduce the PR-1
//! behavior bit-for-bit at fixed seeds.
//!
//! * builder-driven engine reports == direct `engine::run` with a
//!   hand-assembled dataset/partition/config (coop and indep, serial and
//!   threaded); the deeper oracle — stream engine vs the preserved PR-1
//!   engine loops — lives in `coop::engine::tests`.
//! * `TrainStream` reproduces the PR-1 `Trainer` sampling recipes
//!   exactly (seed draw `Pcg64(seed ^ 0x5EED)`, single shared-coin
//!   sampler; per-step re-seeded merged independent sub-batches), which
//!   pins training trajectories: the train-step compute is a
//!   deterministic function of (MFG, params, lr), so identical MFG
//!   sequences at a fixed seed imply identical loss/accuracy curves.

use coopgnn::coop::engine::{self, EngineConfig, EngineReport, ExecMode, Mode};
use coopgnn::graph::{datasets, partition};
use coopgnn::pipeline::{
    with_prefetch, Batching, MinibatchStream, PipelineBuilder, TrainStream, SEED_DRAW_SALT,
};
use coopgnn::sampling::{block, Kappa, Mfg, SamplerConfig, SamplerKind};
use coopgnn::train::sample_indep_parts;
use coopgnn::util::rng::Pcg64;

fn assert_counts_identical(a: &EngineReport, b: &EngineReport, ctx: &str) {
    assert_eq!(a.s, b.s, "{ctx}: S");
    assert_eq!(a.e, b.e, "{ctx}: E");
    assert_eq!(a.tilde, b.tilde, "{ctx}: S~");
    assert_eq!(a.cross, b.cross, "{ctx}: cross");
    assert_eq!(a.feat_requested, b.feat_requested, "{ctx}: requested");
    assert_eq!(a.feat_misses, b.feat_misses, "{ctx}: misses");
    assert_eq!(a.feat_fabric_rows, b.feat_fabric_rows, "{ctx}: fabric");
    assert_eq!(a.cache_miss_rate, b.cache_miss_rate, "{ctx}: miss rate");
    assert_eq!(a.feat_storage_bytes, b.feat_storage_bytes, "{ctx}: storage bytes");
    assert_eq!(a.feat_fabric_bytes, b.feat_fabric_bytes, "{ctx}: fabric bytes");
    assert_eq!(a.feat_fabric_inter_bytes, b.feat_fabric_inter_bytes, "{ctx}: inter bytes");
    assert_eq!(a.derived_miss_rate, b.derived_miss_rate, "{ctx}: derived rate");
    assert_eq!(a.dup_factor, b.dup_factor, "{ctx}: dup");
}

#[test]
fn builder_reports_match_direct_engine_run() {
    // the builder path (dataset seeded from cfg.seed, random partition
    // seeded from cfg.seed) vs assembling the same pieces by hand and
    // calling engine::run directly — both modes, both exec modes
    let seed = 0x5EA5;
    for mode in [Mode::Independent, Mode::Cooperative] {
        for exec in [ExecMode::Serial, ExecMode::Threaded] {
            let pipe = PipelineBuilder::new()
                .dataset("tiny")
                .mode(mode)
                .exec(exec)
                .num_pes(4)
                .batch_per_pe(32)
                .cache_per_pe(200)
                .warmup_batches(2)
                .measure_batches(4)
                .seed(seed)
                .build()
                .unwrap();
            let via_pipeline = pipe.engine_report();

            let ds = datasets::build("tiny", seed).unwrap();
            let part = partition::random(&ds.graph, 4, seed);
            let cfg = EngineConfig {
                mode,
                exec,
                num_pes: 4,
                batch_per_pe: 32,
                cache_per_pe: 200,
                warmup_batches: 2,
                measure_batches: 4,
                seed,
                ..Default::default()
            };
            let direct = engine::run(&ds, &part, &cfg);
            assert_counts_identical(
                &via_pipeline,
                &direct,
                &format!("{}/{}", mode.name(), exec.name()),
            );
        }
    }
}

#[test]
fn pipeline_stream_drained_by_trait_object_matches_report() {
    // Pipeline::stream() + engine::drain over &mut dyn MinibatchStream
    // is the same thing engine_report() does internally
    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .num_pes(4)
        .batch_per_pe(32)
        .cache_per_pe(200)
        .warmup_batches(1)
        .measure_batches(3)
        .seed(9)
        .build()
        .unwrap();
    let mut stream = pipe.stream();
    let drained = engine::drain(&mut stream, &pipe.cfg.engine_config(&pipe.ds));
    let report = pipe.engine_report();
    assert_counts_identical(&drained, &report, "drain vs engine_report");
}

fn assert_mfgs_equal(a: &Mfg, b: &Mfg, ctx: &str) {
    assert_eq!(a.layer_vertices, b.layer_vertices, "{ctx}: vertices");
    for (l, (ea, eb)) in a.layer_edges.iter().zip(&b.layer_edges).enumerate() {
        assert_eq!(ea.offsets, eb.offsets, "{ctx}: L{l} offsets");
        assert_eq!(ea.nbr_local, eb.nbr_local, "{ctx}: L{l} edges");
    }
}

#[test]
fn single_train_stream_reproduces_pr1_trainer_sampling() {
    // PR-1 Trainer::step sampled like this: seeds from
    // Pcg64(seed ^ 0x5EED) over the train split, one persistent
    // shared-coin sampler built with `seed`, advance_batch per step.
    // TrainStream::Single must yield the identical MFG sequence — and
    // since the train-step compute is deterministic in the MFG, this
    // pins the loss/accuracy trajectory at a fixed seed.
    let ds = datasets::build("tiny", 3).unwrap();
    let seed = 0x7EA1;
    let batch = 32usize;
    let cfg = SamplerConfig::default();

    let mut stream = TrainStream::new(
        &ds,
        SamplerKind::Labor0,
        cfg,
        batch,
        seed,
        ExecMode::Threaded,
        Batching::Single,
    );

    // the PR-1 recipe, inline
    let mut legacy_sampler = cfg.build(SamplerKind::Labor0, &ds.graph, seed);
    let mut legacy_rng = Pcg64::new(seed ^ SEED_DRAW_SALT);

    for step in 0..5 {
        let b = batch.min(ds.train.len());
        let legacy_seeds: Vec<u32> = legacy_rng
            .sample_distinct(ds.train.len(), b)
            .into_iter()
            .map(|i| ds.train[i as usize])
            .collect();
        let legacy_mfg = legacy_sampler.sample_mfg(&legacy_seeds);
        legacy_sampler.advance_batch();

        let mb = stream.next_batch();
        let stream_mfg = mb.merged.expect("train stream yields MFGs");
        assert_eq!(stream_mfg.seeds(), legacy_seeds.as_slice(), "step {step}: seed draw");
        assert_mfgs_equal(&stream_mfg, &legacy_mfg, &format!("step {step}"));
    }
}

#[test]
fn indep_merged_train_stream_reproduces_pr1_fig9_recipe() {
    // PR-1 Figure 9 independent arm: per-step batch seed
    // `seed ^ (step << 16)` (step 1-based), P sub-batches with sampler
    // seeds `batch_seed ^ ((i+1) << 32)`, merged block-diagonally.
    let ds = datasets::build("tiny", 3).unwrap();
    let seed = 0xBEEF;
    let batch = 32usize;
    let p = 4usize;
    let cfg = SamplerConfig::default();

    let mut stream = TrainStream::new(
        &ds,
        SamplerKind::Labor0,
        cfg,
        batch,
        seed,
        ExecMode::Threaded,
        Batching::IndepMerged { pes: p },
    );
    let mut legacy_rng = Pcg64::new(seed ^ SEED_DRAW_SALT);

    for step in 1u64..=4 {
        let b = batch.min(ds.train.len());
        let legacy_seeds: Vec<u32> = legacy_rng
            .sample_distinct(ds.train.len(), b)
            .into_iter()
            .map(|i| ds.train[i as usize])
            .collect();
        let batch_seed = seed ^ (step << 16);
        let parts = sample_indep_parts(
            &ds.graph,
            cfg,
            SamplerKind::Labor0,
            &legacy_seeds,
            p,
            batch_seed,
            ExecMode::Serial,
        );
        let legacy_merged = block::merge_mfgs(&parts);

        let mb = stream.next_batch();
        let stream_mfg = mb.merged.expect("train stream yields MFGs");
        assert_mfgs_equal(&stream_mfg, &legacy_merged, &format!("step {step}"));
    }
}

#[test]
fn kappa_flows_through_the_builder() {
    // dependent minibatching is a config knob on the same stream: κ=64
    // must cut the miss rate exactly as it does through raw EngineConfig
    let mk = |kappa: Kappa| {
        let mut pipe = PipelineBuilder::new()
            .dataset("tiny")
            .mode(Mode::Independent)
            .num_pes(1)
            .batch_per_pe(64)
            .cache_per_pe(400)
            .warmup_batches(4)
            .measure_batches(12)
            .seed(1)
            .build()
            .unwrap();
        pipe.cfg.kappa = kappa;
        pipe.engine_report()
    };
    let r1 = mk(Kappa::Finite(1));
    let r64 = mk(Kappa::Finite(64));
    assert!(
        r64.cache_miss_rate < r1.cache_miss_rate,
        "κ=64 miss {} must beat κ=1 {}",
        r64.cache_miss_rate,
        r1.cache_miss_rate
    );
}

#[test]
fn prefetched_train_stream_is_bit_identical_to_inline() {
    // The training-path determinism contract behind `--prefetch 1`:
    // the prefetched stream yields the same MFGs *and the same feature
    // bytes* as the inline stream at a fixed seed. The train-step
    // compute is a deterministic function of (MFG, features, params,
    // lr), so this pins loss/accuracy trajectories prefetch on vs off.
    let ds = datasets::build("tiny", 9).unwrap();
    let cfg = SamplerConfig::default();
    for batching in [Batching::Single, Batching::IndepMerged { pes: 4 }] {
        let mk = || {
            TrainStream::new(&ds, SamplerKind::Labor0, cfg, 32, 21, ExecMode::Threaded, batching)
        };
        let mut inline = mk();
        let direct: Vec<_> = (0..4).map(|_| inline.next_batch()).collect();
        let prefetched: Vec<_> =
            with_prefetch(mk(), |s| (0..4).map(|_| s.next_batch()).collect());
        for (i, (a, b)) in direct.iter().zip(&prefetched).enumerate() {
            let am = a.merged.as_ref().unwrap();
            let bm = b.merged.as_ref().unwrap();
            assert_mfgs_equal(am, bm, &format!("{batching:?} batch {i}"));
            assert_eq!(
                a.per_pe[0].features, b.per_pe[0].features,
                "{batching:?} batch {i}: feature bytes"
            );
            assert_eq!(a.per_pe[0].bytes_from_storage, b.per_pe[0].bytes_from_storage);
        }
    }
}

#[test]
fn train_stream_features_match_trainer_clip_contract() {
    // the trainer memcpys a prefix of the shipped buffer into its padded
    // tensor; the stream must therefore ship S^L rows in order
    let ds = datasets::build("tiny", 10).unwrap();
    let cfg = SamplerConfig::default();
    let mut s = TrainStream::new(
        &ds,
        SamplerKind::Labor0,
        cfg,
        24,
        5,
        ExecMode::Serial,
        Batching::Single,
    );
    let mb = s.next_batch();
    let mfg = mb.merged.unwrap();
    let feats = mb.per_pe[0].features.as_ref().unwrap();
    let d = ds.feat_dim;
    assert_eq!(feats.len(), mfg.input_vertices().len() * d);
    let mut row = vec![0f32; d];
    for (i, &v) in mfg.input_vertices().iter().enumerate().step_by(7) {
        ds.write_features(v, &mut row);
        assert_eq!(&feats[i * d..(i + 1) * d], &row[..], "row {i}");
    }
}

#[test]
fn train_stream_exec_modes_agree() {
    // Batching::IndepMerged must be scheduling-independent: serial and
    // threaded sub-batch sampling produce the same merged MFG stream
    let ds = datasets::build("tiny", 5).unwrap();
    let cfg = SamplerConfig::default();
    let mut mk = |exec: ExecMode| {
        let mut s = TrainStream::new(
            &ds,
            SamplerKind::Labor0,
            cfg,
            32,
            7,
            exec,
            Batching::IndepMerged { pes: 4 },
        );
        (0..3).map(|_| s.next_batch().merged.unwrap()).collect::<Vec<_>>()
    };
    let serial = mk(ExecMode::Serial);
    let threaded = mk(ExecMode::Threaded);
    for (i, (a, b)) in serial.iter().zip(&threaded).enumerate() {
        assert_mfgs_equal(a, b, &format!("batch {i}"));
    }
}
