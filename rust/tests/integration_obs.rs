//! Observability-plane acceptance gates (the PR's acceptance criteria):
//!
//! * the serve flight-recorder trace is **byte-identical** across
//!   `--exec serial|threaded` × `--prefetch 0|1` at a fixed seed (it is
//!   a pure function of the ledger, which already carries that
//!   contract);
//! * engine and train counter ledgers are **bit-identical with tracing
//!   on vs off** — spans are derived from the ledgers after the fact,
//!   never consulted;
//! * per-stage summed span bytes **reconcile exactly** with the
//!   corresponding `EngineReport` / `ParallelRunReport` / `ServeReport`
//!   ledger fields (integer sums < 2^53, so the f64 divisions match
//!   bit-for-bit, not approximately).

use coopgnn::coop::all_to_all::AllReduceStrategy;
use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::obs::Trace;
use coopgnn::pipeline::{Pipeline, PipelineBuilder};
use coopgnn::serve::{BatcherKind, ServeConfig, ServeOutcome, WorkloadKind};

/// Two independently built pipelines over the same config so the traced
/// and untraced runs cannot share mutable state.
fn engine_pipe(hot_mb: usize, prefetch: bool) -> Pipeline {
    PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .num_pes(2)
        .seed(77)
        .hot_mb(hot_mb)
        .prefetch(prefetch)
        .warmup_batches(2)
        .measure_batches(6)
        .build()
        .unwrap()
}

fn run_serve(exec: ExecMode, prefetch: bool) -> ServeOutcome {
    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .exec(exec)
        .num_pes(2)
        .prefetch(prefetch)
        .seed(13)
        .build()
        .unwrap();
    let scfg = ServeConfig {
        rate_per_s: 15_000.0,
        slo_us: 25_000,
        batcher: BatcherKind::Adaptive,
        duration_batches: 8,
        fixed_batch_per_pe: 8,
        workload: WorkloadKind::OpenPoisson,
        clients: 16,
        ..Default::default()
    };
    pipe.server(scfg).unwrap().run()
}

fn bits_eq(a: f64, b: f64, what: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b} must match bit-for-bit");
}

/// `serve --trace` acceptance gate: the exported Chrome JSON is
/// byte-identical across every exec × prefetch combination — the trace
/// inherits the ledger's bit-identity contract wholesale.
#[test]
fn serve_trace_json_is_byte_identical_across_exec_and_prefetch() {
    let baseline = run_serve(ExecMode::Serial, false);
    let json = baseline.ledger.trace().to_chrome_json();
    assert!(baseline.ledger.requests.len() > 8, "sim must serve requests");
    assert!(json.len() > 2, "trace must carry spans");
    for (exec, prefetch) in [
        (ExecMode::Serial, true),
        (ExecMode::Threaded, false),
        (ExecMode::Threaded, true),
    ] {
        let other = run_serve(exec, prefetch).ledger.trace().to_chrome_json();
        assert_eq!(json, other, "{exec:?}/prefetch={prefetch}: serve trace drifted");
    }
}

/// Serve reconciliation: per-stage span bytes equal the batch-ledger
/// sums exactly (u64), and dividing by the served count reproduces the
/// `ServeReport` per-request fields bit-for-bit — the same integer
/// sums, the same single f64 division.
#[test]
fn serve_trace_bytes_reconcile_with_report() {
    let out = run_serve(ExecMode::Threaded, true);
    let t = out.ledger.trace();
    let storage: u64 = out.ledger.batches.iter().map(|b| b.storage_bytes).sum();
    let fabric: u64 = out.ledger.batches.iter().map(|b| b.fabric_bytes).sum();
    let hot: u64 = out.ledger.batches.iter().map(|b| b.hot_bytes).sum();
    assert_eq!(t.stage_bytes("serve_storage"), storage);
    assert_eq!(t.stage_bytes("serve_fabric"), fabric);
    assert_eq!(t.stage_bytes("serve_hot"), hot);
    assert!(storage > 0, "batches must move storage bytes");
    let n = out.report.served as f64;
    bits_eq(
        t.stage_bytes("serve_storage") as f64 / n,
        out.report.storage_bytes_per_req,
        "serve_storage / served vs storage_bytes_per_req",
    );
    bits_eq(
        t.stage_bytes("serve_fabric") as f64 / n,
        out.report.fabric_bytes_per_req,
        "serve_fabric / served vs fabric_bytes_per_req",
    );
    bits_eq(
        t.stage_bytes("serve_hot") as f64 / n,
        out.report.hot_bytes_per_req,
        "serve_hot / served vs hot_bytes_per_req",
    );
    // Track 0 batch sub-spans tile each service window: per batch, span
    // starts/ends chain and cover [dispatch, dispatch + service].
    let m = t.merged();
    for b in &out.ledger.batches {
        let spans: Vec<_> =
            m.iter().filter(|s| s.pe == 0 && s.batch == b.index as u64).collect();
        assert_eq!(spans.len(), 3, "three byte stages per dispatched batch");
        assert_eq!(spans.first().unwrap().t_start_us, b.dispatch_us);
        assert_eq!(spans.last().unwrap().t_end_us, b.dispatch_us + b.service_us);
        for w in spans.windows(2) {
            assert_eq!(w[0].t_end_us, w[1].t_start_us, "stages must tile the window");
        }
    }
}

/// Engine counters are bit-identical with the flight recorder on vs
/// off. Wall-clock fields (`wall_*_ms`) are honest measurements and
/// differ run to run; every deterministic field must match exactly.
#[test]
fn engine_counters_identical_with_tracing_on_vs_off() {
    let plain = engine_pipe(1, true).engine_report();
    let mut trace = Trace::on("engine");
    let traced = engine_pipe(1, true).engine_report_traced(&mut trace);
    assert!(
        trace.buffer().unwrap().span_count() > 0,
        "traced run must have recorded spans"
    );
    for (a, b, what) in [
        (&plain.s, &traced.s, "s"),
        (&plain.e, &traced.e, "e"),
        (&plain.tilde, &traced.tilde, "tilde"),
        (&plain.cross, &traced.cross, "cross"),
    ] {
        assert_eq!(a.len(), b.len(), "{what}: layer counts");
        for (x, y) in a.iter().zip(b.iter()) {
            bits_eq(*x, *y, what);
        }
    }
    for (a, b, what) in [
        (plain.feat_requested, traced.feat_requested, "feat_requested"),
        (plain.feat_misses, traced.feat_misses, "feat_misses"),
        (plain.feat_fabric_rows, traced.feat_fabric_rows, "feat_fabric_rows"),
        (plain.cache_miss_rate, traced.cache_miss_rate, "cache_miss_rate"),
        (plain.feat_storage_bytes, traced.feat_storage_bytes, "feat_storage_bytes"),
        (plain.feat_fabric_bytes, traced.feat_fabric_bytes, "feat_fabric_bytes"),
        (
            plain.feat_fabric_inter_bytes,
            traced.feat_fabric_inter_bytes,
            "feat_fabric_inter_bytes",
        ),
        (plain.derived_miss_rate, traced.derived_miss_rate, "derived_miss_rate"),
        (plain.feat_hot_rows, traced.feat_hot_rows, "feat_hot_rows"),
        (plain.feat_hot_bytes, traced.feat_hot_bytes, "feat_hot_bytes"),
        (plain.hot_hit_rate, traced.hot_hit_rate, "hot_hit_rate"),
        (plain.prefetch_rows, traced.prefetch_rows, "prefetch_rows"),
        (plain.prefetch_bytes, traced.prefetch_bytes, "prefetch_bytes"),
        (plain.dup_factor, traced.dup_factor, "dup_factor"),
    ] {
        bits_eq(a, b, what);
    }
}

/// Engine reconciliation: per-stage span bytes divided by the measured
/// batch count reproduce the `EngineReport` byte fields bit-for-bit —
/// the reduction sums the same `PeWork` integers the spans carry. A
/// hot tier + prefetch exercise every byte stage.
#[test]
fn engine_trace_bytes_reconcile_with_report() {
    let measure = 6u64;
    let mut trace = Trace::on("engine");
    let rep = engine_pipe(1, true).engine_report_traced(&mut trace);
    let t = trace.buffer().unwrap();
    assert_eq!(
        t.batch_count() as u64,
        measure,
        "only measured batches emit spans"
    );
    let m = measure as f64;
    bits_eq(
        t.stage_bytes("cache_fill") as f64 / m,
        rep.feat_storage_bytes,
        "cache_fill vs feat_storage_bytes",
    );
    bits_eq(
        t.stage_bytes("fabric_all_to_all") as f64 / m,
        rep.feat_fabric_bytes,
        "fabric_all_to_all vs feat_fabric_bytes",
    );
    bits_eq(
        t.stage_bytes("hot_fill") as f64 / m,
        rep.feat_hot_bytes,
        "hot_fill vs feat_hot_bytes",
    );
    bits_eq(
        t.stage_bytes("prefetch") as f64 / m,
        rep.prefetch_bytes,
        "prefetch vs prefetch_bytes",
    );
    assert!(rep.feat_storage_bytes > 0.0, "config must move storage bytes");
    assert!(rep.feat_fabric_bytes > 0.0, "coop mode must move fabric bytes");
    // The merge key is a strict total order over every span.
    let merged = t.merged();
    for w in merged.windows(2) {
        assert!(
            (w[0].batch, w[0].pe, w[0].seq) < (w[1].batch, w[1].pe, w[1].seq),
            "span merge key must be strictly increasing"
        );
    }
}

/// Train counters are bit-identical with the flight recorder on vs off,
/// and the trace's byte stages reconcile with the run report exactly
/// (wall-derived span *times* differ run to run; the bytes never do).
#[test]
fn train_counters_identical_with_tracing_and_bytes_reconcile() {
    let steps = 5usize;
    let run = |traced: bool| {
        let pipe = engine_pipe(0, false);
        let mut stream = pipe.stream();
        let mut trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
        if traced {
            trainer.enable_trace();
        }
        let rep = trainer.run(&mut stream, steps, &pipe.ds.labels);
        assert!(trainer.replicas_in_lockstep(), "replicas diverged");
        let buf = trainer.trace().buffer().cloned();
        (rep, buf)
    };
    let (plain, none) = run(false);
    let (traced, buf) = run(true);
    assert!(none.is_none(), "untraced trainer must hold no buffer");
    let buf = buf.expect("traced trainer must hold a buffer");

    assert_eq!(plain.steps, traced.steps);
    assert_eq!(plain.collective, traced.collective);
    for (a, b, what) in [
        (plain.examples_per_step, traced.examples_per_step, "examples_per_step"),
        (
            plain.storage_bytes_per_step,
            traced.storage_bytes_per_step,
            "storage_bytes_per_step",
        ),
        (plain.fabric_bytes_per_step, traced.fabric_bytes_per_step, "fabric_bytes_per_step"),
        (plain.grad_bytes_per_step, traced.grad_bytes_per_step, "grad_bytes_per_step"),
        (plain.act_bytes_per_step, traced.act_bytes_per_step, "act_bytes_per_step"),
        (
            plain.fabric_inter_bytes_per_step,
            traced.fabric_inter_bytes_per_step,
            "fabric_inter_bytes_per_step",
        ),
        (
            plain.grad_inter_bytes_per_step,
            traced.grad_inter_bytes_per_step,
            "grad_inter_bytes_per_step",
        ),
        (
            plain.act_inter_bytes_per_step,
            traced.act_inter_bytes_per_step,
            "act_inter_bytes_per_step",
        ),
    ] {
        bits_eq(a, b, what);
    }
    for (a, b, what) in [
        (plain.first_loss, traced.first_loss, "first_loss"),
        (plain.last_loss, traced.last_loss, "last_loss"),
        (plain.last_acc, traced.last_acc, "last_acc"),
    ] {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: {a} vs {b}");
    }

    // Byte reconciliation: stage sums / steps == per-step report fields.
    let s = steps as f64;
    bits_eq(
        buf.stage_bytes("cache_fill") as f64 / s,
        traced.storage_bytes_per_step,
        "cache_fill vs storage_bytes_per_step",
    );
    bits_eq(
        buf.stage_bytes("fabric_all_to_all") as f64 / s,
        traced.fabric_bytes_per_step,
        "fabric_all_to_all vs fabric_bytes_per_step",
    );
    bits_eq(
        buf.stage_bytes("grad_allreduce") as f64 / s,
        traced.grad_bytes_per_step,
        "grad_allreduce vs grad_bytes_per_step",
    );
    bits_eq(
        buf.stage_bytes("act_exchange") as f64 / s,
        traced.act_bytes_per_step,
        "act_exchange vs act_bytes_per_step",
    );
    assert!(traced.grad_bytes_per_step > 0.0, "all-reduce must move bytes");

    // The coordinator track (tid = num_pes) carries one
    // compute / act_exchange / grad_allreduce triple per step.
    let coord: Vec<_> = buf.merged().into_iter().filter(|sp| sp.pe == 2).collect();
    assert_eq!(coord.len(), 3 * steps, "coordinator emits three spans per step");
    assert!(coord.iter().any(|sp| sp.stage == "compute"));
    assert!(coord.iter().any(|sp| sp.stage == "grad_allreduce"));
}
