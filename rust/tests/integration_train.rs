//! Training-path integration on the `conv` dataset (the convergence-study
//! twin): κ-dependence leaves single-batch distributions intact, merged
//! independent batches train, and the full repro harness plumbing works
//! end to end in quick mode.

use coopgnn::graph::datasets;
use coopgnn::repro::{self, Ctx};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::Kappa;
use coopgnn::train::{Trainer, TrainerOptions};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(Box::leak(p.into_boxed_path()))
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// Execution-runtime gate: this build may ship the PJRT stub, in which
/// case every runtime-dependent test skips (even when artifacts exist).
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn kappa_dependent_training_converges_like_independent() {
    // Table 3's central claim, scaled down: κ=64 training quality is
    // within noise of κ=1 on a short run.
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let ds = datasets::build("tiny", 9).unwrap();
    let mut accs = Vec::new();
    for kappa in [Kappa::Finite(1), Kappa::Finite(64)] {
        let opts = TrainerOptions { kappa, lr: Some(0.02), seed: 31, ..Default::default() };
        let mut t = Trainer::new(&rt, &manifest, "tiny-b32", &ds, &opts).unwrap();
        for _ in 0..120 {
            t.step().unwrap();
        }
        accs.push(t.evaluate(&ds.val, 5).unwrap().accuracy);
    }
    let (a1, a64) = (accs[0], accs[1]);
    assert!(
        (a1 - a64).abs() < 0.12,
        "κ=64 must not derail convergence: κ=1 {a1:.3} vs κ=64 {a64:.3}"
    );
}

#[test]
fn quick_repro_harnesses_run_end_to_end() {
    // Smoke the whole harness plumbing (fig3/fig5/table4/table7/scaling
    // already covered by their own unit tests; here: table3 + fig9 which
    // need PJRT).
    let Some(dir) = artifacts_dir() else { return };
    let Some(_rt) = runtime() else { return };
    let out = std::env::temp_dir().join("coopgnn_repro_quick");
    let ctx = Ctx {
        out: out.clone(),
        quick: true,
        seed: 0xBEEF,
        artifacts: dir.to_path_buf(),
        ..Default::default()
    };
    repro::run("table3", &ctx).unwrap();
    assert!(out.join("table3.csv").exists());
    assert!(out.join("fig4.csv").exists());
    repro::run("fig9", &ctx).unwrap();
    assert!(out.join("fig9.csv").exists());
    // coop and indep finals should both exist and be sane
    let fig9 = std::fs::read_to_string(out.join("fig9.csv")).unwrap();
    let finals: Vec<f64> = fig9
        .lines()
        .skip(1)
        .filter_map(|l| l.split(',').nth(3)?.parse().ok())
        .collect();
    assert!(!finals.is_empty());
    assert!(finals.iter().all(|a| (0.0..=1.0).contains(a)));
    std::fs::remove_dir_all(&out).ok();
}
