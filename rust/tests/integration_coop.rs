//! Engine-level integration: Cooperative vs Independent across datasets,
//! partitioners, and PE counts — the invariants behind Tables 4–7.

use coopgnn::coop::engine::{run as engine_run, EngineConfig, ExecMode, Mode};
use coopgnn::costmodel::{estimate, ModelCost, PRESETS};
use coopgnn::graph::{datasets, partition};
use coopgnn::sampling::{Kappa, SamplerKind};

fn cfg(mode: Mode, pes: usize, b: usize) -> EngineConfig {
    EngineConfig {
        mode,
        num_pes: pes,
        batch_per_pe: b,
        cache_per_pe: 500,
        warmup_batches: 2,
        measure_batches: 4,
        seed: 99,
        ..Default::default()
    }
}

#[test]
fn coop_advantage_grows_with_pe_count() {
    // Theorem 3.1 consequence: at fixed global batch, indep per-PE work
    // tracks |S^L(B/P)| while coop tracks |S^L(B)|/P — the gap widens
    // with P.
    let ds = datasets::build("tiny", 4).unwrap();
    let global = 128usize;
    let mut gaps = Vec::new();
    for p in [2usize, 4, 8] {
        let part = partition::random(&ds.graph, p, 1);
        let ri = engine_run(&ds, &part, &cfg(Mode::Independent, p, global / p));
        let rc = engine_run(&ds, &part, &cfg(Mode::Cooperative, p, global / p));
        let gap = ri.s[3] / rc.s[3].max(1.0);
        gaps.push(gap);
    }
    assert!(gaps[0] > 1.0, "coop must do less per-PE work: {gaps:?}");
    assert!(
        gaps[2] > gaps[0],
        "advantage must grow with P (paper Table 5 shape): {gaps:?}"
    );
}

#[test]
fn every_sampler_supports_both_modes() {
    let ds = datasets::build("tiny", 5).unwrap();
    let part = partition::random(&ds.graph, 4, 2);
    for kind in SamplerKind::ALL {
        for mode in [Mode::Independent, Mode::Cooperative] {
            let mut c = cfg(mode, 4, 16);
            c.kind = kind;
            c.sampler.rw.num_walks = 10;
            let r = engine_run(&ds, &part, &c);
            assert!(r.s[3] > 0.0, "{kind:?}/{mode:?} produced no work");
        }
    }
}

#[test]
fn metis_partition_cuts_coop_cross_traffic_and_estimated_time() {
    let ds = datasets::build("conv", 6).unwrap();
    let rand_p = partition::random(&ds.graph, 4, 3);
    let metis_p = partition::multilevel(&ds.graph, 4, 3);
    let rr = engine_run(&ds, &rand_p, &cfg(Mode::Cooperative, 4, 128));
    let rm = engine_run(&ds, &metis_p, &cfg(Mode::Cooperative, 4, 128));
    let cross_r: f64 = rr.cross.iter().sum();
    let cross_m: f64 = rm.cross.iter().sum();
    assert!(
        cross_m < cross_r,
        "multilevel must cut cross ids: {cross_m} vs {cross_r}"
    );
    // Note: total *time* can go either way — partitioning trades fabric
    // traffic against per-PE load imbalance (the paper observes exactly
    // this on mag240M, Appendix A.6 obs. 5) — so we only require that
    // the communication term shrank and the estimates stay finite.
    let model = ModelCost::gcn(ds.feat_dim, 64);
    let tr = estimate(&rr, &PRESETS[0], &model, ds.feat_dim);
    let tm = estimate(&rm, &PRESETS[0], &model, ds.feat_dim);
    assert!(tm.total_ms().is_finite() && tr.total_ms().is_finite());
}

#[test]
fn dependent_kappa_mass_effect_on_coop_caches() {
    // Figure 5b: κ helps cooperative caching too. Needs a graph whose
    // per-batch working set does not cover the per-PE vertex universe
    // (conv/tiny are too small — every row ends up cached regardless).
    let ds = datasets::build("flickr-s", 7).unwrap();
    let part = partition::random(&ds.graph, 4, 4);
    let mut c1 = cfg(Mode::Cooperative, 4, 1024);
    // per-PE cache slightly above the per-PE working set (~|S³(4b)|/4):
    // below it LRU scan-thrash pins the miss rate at 1 for every κ
    c1.cache_per_pe = ds.cache_size * 3 / 10;
    c1.warmup_batches = 4;
    c1.measure_batches = 10;
    let mut c256 = c1.clone();
    c256.sampler.kappa = Kappa::Finite(256);
    let r1 = engine_run(&ds, &part, &c1);
    let r256 = engine_run(&ds, &part, &c256);
    assert!(
        r256.cache_miss_rate < r1.cache_miss_rate,
        "κ=256 coop miss {} must beat κ=1 {}",
        r256.cache_miss_rate,
        r1.cache_miss_rate
    );
}

#[test]
fn indep_mode_has_no_fabric_traffic() {
    let ds = datasets::build("tiny", 8).unwrap();
    let part = partition::random(&ds.graph, 4, 5);
    let r = engine_run(&ds, &part, &cfg(Mode::Independent, 4, 32));
    assert!(r.cross.iter().all(|&c| c == 0.0));
    assert_eq!(r.feat_fabric_rows, 0.0);
    assert!(r.dup_factor >= 1.0);
}

#[test]
fn presets_cover_paper_systems() {
    assert_eq!(PRESETS.len(), 3);
    assert!(PRESETS.iter().any(|p| p.num_pes == 16));
}

/// Engine determinism across execution runtimes: the thread-per-PE engine
/// and the serial reference must produce identical `EngineReport`
/// vertex/edge/communication/cache counts for a fixed seed — for both
/// modes, several samplers, and κ>1 dependent batches.
#[test]
fn thread_per_pe_engine_matches_serial_reference() {
    let ds = datasets::build("tiny", 21).unwrap();
    let part = partition::random(&ds.graph, 4, 9);
    for kind in [SamplerKind::Labor0, SamplerKind::Neighbor] {
        for mode in [Mode::Independent, Mode::Cooperative] {
            for kappa in [Kappa::Finite(1), Kappa::Finite(32)] {
                let mut serial = cfg(mode, 4, 32);
                serial.kind = kind;
                serial.sampler.kappa = kappa;
                serial.exec = ExecMode::Serial;
                let mut threaded = serial.clone();
                threaded.exec = ExecMode::Threaded;
                let a = engine_run(&ds, &part, &serial);
                let b = engine_run(&ds, &part, &threaded);
                let ctx = format!("{kind:?}/{mode:?}/κ={:?}", kappa);
                assert_eq!(a.s, b.s, "{ctx}: S counts");
                assert_eq!(a.e, b.e, "{ctx}: E counts");
                assert_eq!(a.tilde, b.tilde, "{ctx}: S~ counts");
                assert_eq!(a.cross, b.cross, "{ctx}: cross counts");
                assert_eq!(a.feat_requested, b.feat_requested, "{ctx}: requested");
                assert_eq!(a.feat_misses, b.feat_misses, "{ctx}: misses");
                assert_eq!(a.feat_fabric_rows, b.feat_fabric_rows, "{ctx}: fabric rows");
                assert_eq!(a.cache_miss_rate, b.cache_miss_rate, "{ctx}: miss rate");
                assert_eq!(a.dup_factor, b.dup_factor, "{ctx}: dup factor");
            }
        }
    }
}

/// The threaded engine must report a real per-batch wall clock. The
/// strict concurrency demonstration (threaded batch wall < serial batch
/// wall on the identical workload) lives in `benches/bench_coop.rs`
/// where batches are big enough to dominate scheduling noise.
#[test]
fn threaded_engine_reports_batch_wall_clock() {
    let ds = datasets::build("tiny", 22).unwrap();
    let part = partition::random(&ds.graph, 4, 10);
    let r = engine_run(&ds, &part, &cfg(Mode::Cooperative, 4, 64));
    assert!(r.wall_batch_ms > 0.0, "wall clock must be measured");
    assert!(r.wall_sampling_ms > 0.0, "per-PE sampling time must be measured");
}

/// Counter conservation across the replicated fabric (the invariant
/// behind the lint plane's `ledger` rule): on an 8-PE r=2 run every
/// `inter_*` counter must actually reach its report, be positive (two
/// replica groups force inter-group traffic), never exceed the total it
/// was carved from, and stay below it (intra-group traffic exists too).
/// The serve plane's copy of this bug — `fabric_inter_bytes` dropped on
/// the way into `BatchRecord` — is pinned in `serve/report.rs` tests.
#[test]
fn replicated_inter_ledgers_are_conserved() {
    use coopgnn::coop::all_to_all::AllReduceStrategy;
    use coopgnn::pipeline::PipelineBuilder;

    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(Mode::Cooperative)
        .num_pes(8)
        .replication(2)
        .batch_per_pe(16)
        .seed(33)
        .build()
        .unwrap();

    // engine ledger: the feature-fabric inter slice
    let er = pipe.engine_report();
    assert!(er.feat_fabric_bytes > 0.0, "coop run must ship fabric rows");
    assert!(
        er.feat_fabric_inter_bytes > 0.0,
        "r=2 must produce inter-group feature traffic"
    );
    assert!(
        er.feat_fabric_inter_bytes <= er.feat_fabric_bytes,
        "inter slice can never exceed the fabric total: {} vs {}",
        er.feat_fabric_inter_bytes,
        er.feat_fabric_bytes
    );
    assert!(
        er.total_cross_bytes() >= er.feat_fabric_inter_bytes,
        "total cross bytes ({}) must bound the inter slice ({})",
        er.total_cross_bytes(),
        er.feat_fabric_inter_bytes
    );

    // training ledgers: feature / gradient / activation inter slices
    // all survive run()'s aggregation
    let mut stream = pipe.stream();
    let mut trainer = pipe.parallel_trainer(0.05, AllReduceStrategy::Ring);
    let rep = trainer.run(&mut stream, 2, &pipe.ds.labels);
    assert!(rep.examples_per_step > 0.0, "examples must be aggregated");
    for (name, inter, total) in [
        ("feature", rep.fabric_inter_bytes_per_step, rep.fabric_bytes_per_step),
        ("gradient", rep.grad_inter_bytes_per_step, rep.grad_bytes_per_step),
        ("activation", rep.act_inter_bytes_per_step, rep.act_bytes_per_step),
    ] {
        assert!(inter > 0.0, "{name}: inter slice must be aggregated into the report");
        assert!(
            inter <= total,
            "{name}: inter ({inter}) can never exceed the total ({total}) it was carved from"
        );
    }
    let inter_sum = rep.fabric_inter_bytes_per_step
        + rep.grad_inter_bytes_per_step
        + rep.act_inter_bytes_per_step;
    let total_sum =
        rep.fabric_bytes_per_step + rep.grad_bytes_per_step + rep.act_bytes_per_step;
    assert!(
        total_sum > inter_sum,
        "at r=2 replica groups must absorb some traffic onto intra links: \
         totals {total_sum} vs inter {inter_sum}"
    );
}
