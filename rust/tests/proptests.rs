//! Property-based tests (via the in-house `propcheck` loop — the offline
//! build has no proptest) over the coordinator's core invariants:
//! sampling, routing/partitioning, batching/state, cache, exchange.

use coopgnn::coop::all_to_all::Exchange;
use coopgnn::coop::cache::LruCache;
use coopgnn::coop::coop_sampler::{partition_seeds, sample_cooperative};
use coopgnn::graph::{generate, partition};
use coopgnn::prop_assert;
use coopgnn::sampling::{block, Kappa, SamplerConfig, SamplerKind};
use coopgnn::util::propcheck::check;
use coopgnn::util::rng::Pcg64;

#[test]
fn prop_sampled_neighborhoods_are_subsets() {
    check("subset", 0xA1, 30, |rng| {
        let n = 200 + rng.next_below(800) as usize;
        let deg = 4.0 + rng.next_f64() * 20.0;
        let g = generate::chung_lu(n, deg, 2.5, rng.next_u64());
        let kind = match rng.next_below(3) {
            0 => SamplerKind::Neighbor,
            1 => SamplerKind::Labor0,
            _ => SamplerKind::LaborStar,
        };
        let cfg = SamplerConfig { fanout: 1 + rng.next_below(15) as usize, ..Default::default() };
        let mut s = cfg.build(kind, &g, rng.next_u64());
        let k = 1 + rng.next_below(64) as usize;
        let seeds: Vec<u32> = rng.sample_distinct(n, k);
        let mut out = coopgnn::sampling::Neighborhoods::default();
        out.offsets.push(0);
        s.sample_layer(&seeds, 0, &mut out);
        for (i, &seed) in seeds.iter().enumerate() {
            for &t in out.of(i) {
                prop_assert!(
                    g.neighbors(seed).contains(&t),
                    "{kind:?}: sampled {t} not a neighbor of {seed}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mfg_layers_nest_and_edges_resolve() {
    check("mfg-nesting", 0xA2, 20, |rng| {
        let g = generate::chung_lu(500 + rng.next_below(1500) as usize, 10.0, 2.4, rng.next_u64());
        let cfg = SamplerConfig {
            layers: 1 + rng.next_below(4) as usize,
            fanout: 2 + rng.next_below(12) as usize,
            ..Default::default()
        };
        let mut s = cfg.build(SamplerKind::Labor0, &g, rng.next_u64());
        let k = 1 + rng.next_below(64) as usize;
        let seeds: Vec<u32> = rng.sample_distinct(g.num_vertices(), k);
        let mfg = s.sample_mfg(&seeds);
        for l in 0..mfg.num_layers() {
            let a = &mfg.layer_vertices[l];
            let b = &mfg.layer_vertices[l + 1];
            prop_assert!(b.len() >= a.len(), "layer {l} shrank");
            prop_assert!(&b[..a.len()] == &a[..], "layer {l} not a prefix");
            let e = &mfg.layer_edges[l];
            for i in 0..a.len() {
                for &j in e.of(i) {
                    prop_assert!((j as usize) < b.len(), "edge index out of range");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partition_is_exact_cover_and_coop_union_disjoint() {
    check("routing", 0xA3, 12, |rng| {
        let g = generate::chung_lu(800 + rng.next_below(1200) as usize, 8.0, 2.4, rng.next_u64());
        let p_count = 2 + rng.next_below(7) as usize;
        let part = match rng.next_below(3) {
            0 => partition::random(&g, p_count, rng.next_u64()),
            1 => partition::ldg(&g, p_count, rng.next_u64()),
            _ => partition::multilevel(&g, p_count, rng.next_u64()),
        };
        let sizes = part.part_sizes();
        prop_assert!(
            sizes.iter().sum::<usize>() == g.num_vertices(),
            "partition must cover all vertices"
        );
        // coop sampling: per-layer owned sets must be disjoint by owner
        let cfg = SamplerConfig { layers: 2, ..Default::default() };
        let mut samplers: Vec<_> =
            (0..p_count).map(|_| cfg.build(SamplerKind::Labor0, &g, 7)).collect();
        let seeds: Vec<u32> = rng.sample_distinct(g.num_vertices(), 64.min(g.num_vertices()));
        let per_pe = partition_seeds(&seeds, &part);
        let coop = sample_cooperative(&g, &part, &mut samplers, &per_pe, 2);
        for l in 0..coop.num_layers() {
            for (p, pl) in coop.layers[l].iter().enumerate() {
                for &v in &pl.owned {
                    prop_assert!(part.part_of(v) == p, "vertex {v} on wrong PE");
                }
            }
        }
        let union = coop.union_layer(2);
        let total: usize = coop.final_owned.iter().map(|v| v.len()).sum();
        prop_assert!(total == union.len(), "owned sets overlap: {total} vs {}", union.len());
        Ok(())
    });
}

#[test]
fn prop_exchange_conserves_items() {
    check("exchange", 0xA4, 40, |rng| {
        let p = 2 + rng.next_below(6) as usize;
        let mut ex = Exchange::new(p);
        let mut buckets: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); p]; p];
        let mut sent = 0usize;
        for row in buckets.iter_mut() {
            for b in row.iter_mut() {
                let k = rng.next_below(20) as usize;
                for _ in 0..k {
                    b.push(rng.next_u64() as u32);
                }
                sent += k;
            }
        }
        let inboxes = ex.route(&buckets, 4);
        let recv: usize = inboxes.iter().map(|b| b.len()).sum();
        prop_assert!(sent == recv, "lost items: sent {sent} recv {recv}");
        prop_assert!(
            ex.cross_items + ex.local_items == sent as u64,
            "accounting mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_lru_never_exceeds_capacity_and_counts_add_up() {
    check("lru", 0xA5, 30, |rng| {
        let cap = 1 + rng.next_below(64) as usize;
        let mut c = LruCache::new(cap);
        let universe = 1 + rng.next_below(200);
        let accesses = 500;
        for _ in 0..accesses {
            c.access(rng.next_below(universe) as u32);
            prop_assert!(c.len() <= cap, "cache overflow");
        }
        prop_assert!(
            c.hits() + c.misses() == accesses as u64,
            "hit+miss must equal accesses"
        );
        Ok(())
    });
}

#[test]
fn prop_padding_weights_normalized_or_zero() {
    check("padding", 0xA6, 15, |rng| {
        let g = generate::chung_lu(600, 12.0, 2.4, rng.next_u64());
        let cfg = SamplerConfig::default();
        let mut s = cfg.build(SamplerKind::Labor0, &g, rng.next_u64());
        let seeds: Vec<u32> = rng.sample_distinct(600, 32);
        let mfg = s.sample_mfg(&seeds);
        let counts = mfg.vertex_counts();
        // randomly squeeze or relax the caps
        let caps = block::ShapeCaps {
            k: 16 + rng.next_below(32) as usize,
            n: counts
                .iter()
                .map(|&c| {
                    let jitter = rng.next_below(40) as i64 - 20;
                    ((c as i64 + jitter).max(4)) as usize
                })
                .collect(),
        };
        let pb = mfg.pad(&caps, |_| 0);
        for l in 0..mfg.num_layers() {
            for i in 0..caps.n[l] {
                let w: f32 = pb.nbr_w[l][i * caps.k..(i + 1) * caps.k].iter().sum::<f32>()
                    + pb.self_w[l][i];
                prop_assert!(
                    (w - 1.0).abs() < 1e-4 || w == 0.0,
                    "row weight must be 1 or 0, got {w} (layer {l} row {i})"
                );
                // indices in range
                for &ix in &pb.nbr_idx[l][i * caps.k..(i + 1) * caps.k] {
                    prop_assert!((ix as usize) < caps.n[l + 1], "nbr idx out of cap");
                }
                prop_assert!(
                    (pb.self_idx[l][i] as usize) < caps.n[l + 1],
                    "self idx out of cap (layer {l} row {i})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dependent_rng_marginally_uniform_any_phase() {
    check("dependent-uniform", 0xA7, 10, |rng| {
        let kappa = 1 + rng.next_below(300) as u32;
        let mut d = coopgnn::sampling::DependentRng::new(rng.next_u64(), Kappa::Finite(kappa));
        for _ in 0..rng.next_below(kappa as u64 * 2) {
            d.advance();
        }
        let n = 5000u64;
        let mean: f64 = (0..n).map(|t| d.vertex_variate(0, t)).sum::<f64>() / n as f64;
        prop_assert!((mean - 0.5).abs() < 0.05, "mean {mean} off at κ={kappa}");
        Ok(())
    });
}

#[test]
fn prop_engine_seed_determinism() {
    // identical config + seed ⇒ identical report (batching/state mgmt is
    // deterministic end to end)
    use coopgnn::coop::engine::{run as engine_run, EngineConfig, Mode};
    use coopgnn::graph::datasets;
    let ds = datasets::build("tiny", 42).unwrap();
    let part = partition::random(&ds.graph, 4, 1);
    let mk = || EngineConfig {
        mode: Mode::Cooperative,
        num_pes: 4,
        batch_per_pe: 32,
        cache_per_pe: 256,
        warmup_batches: 1,
        measure_batches: 3,
        seed: 777,
        ..Default::default()
    };
    let mut a = engine_run(&ds, &part, &mk());
    let mut b = engine_run(&ds, &part, &mk());
    // wall-clock fields are (rightly) not deterministic — zero them
    a.wall_sampling_ms = 0.0;
    a.wall_feature_ms = 0.0;
    a.wall_batch_ms = 0.0;
    b.wall_sampling_ms = 0.0;
    b.wall_feature_ms = 0.0;
    b.wall_batch_ms = 0.0;
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
    let _ = Pcg64::new(0); // keep util linked
}

/// The byte-accounting satellite: storage/fabric byte counters must be
/// exact multiples of the legacy synthetic counts — `misses * row_bytes
/// == bytes_from_storage` and `fabric_rows * row_bytes == fabric_bytes`
/// — per PE, per batch, across modes, exec modes, κ values, and seeds;
/// and the shipped buffers must byte-equal the dataset's hash truth
/// (rows really did travel through cache + store + fabric intact).
#[test]
fn prop_byte_accounting_equals_synthetic_counts() {
    use coopgnn::coop::engine::{EngineConfig, ExecMode, Mode};
    use coopgnn::graph::datasets;
    use coopgnn::pipeline::{EngineStream, MinibatchStream};
    check("byte-accounting", 0xA9, 5, |rng| {
        let ds = datasets::build("tiny", rng.next_u64()).unwrap();
        let rb = ds.row_bytes() as u64;
        let d = ds.feat_dim;
        let p_count = 1 + rng.next_below(4) as usize;
        let part = partition::random(&ds.graph, p_count, rng.next_u64());
        let mode = if rng.next_below(2) == 0 { Mode::Independent } else { Mode::Cooperative };
        let exec = if rng.next_below(2) == 0 { ExecMode::Serial } else { ExecMode::Threaded };
        let kappa =
            if rng.next_below(2) == 0 { Kappa::Finite(1) } else { Kappa::Finite(8) };
        let cfg = EngineConfig {
            mode,
            exec,
            num_pes: p_count,
            batch_per_pe: 8 + rng.next_below(40) as usize,
            cache_per_pe: 64 + rng.next_below(256) as usize,
            seed: rng.next_u64(),
            sampler: SamplerConfig { layers: 2, kappa, ..Default::default() },
            ..Default::default()
        };
        let mut stream = EngineStream::new(&ds, &part, &cfg);
        let mut row = vec![0f32; d];
        for batch in 0..3 {
            let mb = stream.next_batch();
            for (pe, pw) in mb.per_pe.iter().enumerate() {
                let ctx = format!("{mode:?}/{exec:?} batch {batch} PE {pe}");
                prop_assert!(pw.row_bytes == rb, "{ctx}: row_bytes {} vs {rb}", pw.row_bytes);
                prop_assert!(
                    pw.bytes_from_storage == pw.misses * rb,
                    "{ctx}: storage bytes {} != misses {} * {rb}",
                    pw.bytes_from_storage,
                    pw.misses
                );
                prop_assert!(
                    pw.fabric_bytes == pw.fabric * rb,
                    "{ctx}: fabric bytes {} != rows {} * {rb}",
                    pw.fabric_bytes,
                    pw.fabric
                );
                let feats = pw.features.as_ref().expect("engine streams ship buffers");
                let vs = pw.feature_vertices.as_ref().expect("and their vertex lists");
                prop_assert!(
                    feats.len() == vs.len() * d,
                    "{ctx}: buffer shape {} vs {} rows",
                    feats.len(),
                    vs.len()
                );
                // content equals hash truth, independently of the store
                for (i, &v) in vs.iter().enumerate() {
                    ds.write_features(v, &mut row);
                    prop_assert!(
                        feats[i * d..(i + 1) * d] == row[..],
                        "{ctx}: row {i} (vertex {v}) corrupted in transit"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_exec_modes_bit_identical_across_random_configs() {
    // The thread-per-PE runtime must equal the serial reference for any
    // (PE count, batch size, mode, layers) draw — the engine-determinism
    // contract, property-tested.
    use coopgnn::coop::engine::{run as engine_run, EngineConfig, ExecMode, Mode};
    check("exec-mode-equivalence", 0xA8, 6, |rng| {
        let ds = coopgnn::graph::datasets::build_from_spec(
            &coopgnn::graph::datasets::Spec {
                name: "prop",
                mirrors: "property-test twin",
                num_vertices: 800 + rng.next_below(1200) as usize,
                avg_degree: 10.0,
                gamma: 2.4,
                feat_dim: 8,
                num_classes: 4,
                split: (0.5, 0.2, 0.3),
                cache_s3_ratio: 1.5,
                undirected: false,
                community: None,
            },
            rng.next_u64(),
        );
        let p_count = 1 + rng.next_below(6) as usize;
        let part = partition::random(&ds.graph, p_count, rng.next_u64());
        let mode = if rng.next_below(2) == 0 { Mode::Independent } else { Mode::Cooperative };
        let batch = 8 + rng.next_below(48) as usize;
        let seed = rng.next_u64();
        let mk = |exec: ExecMode| EngineConfig {
            mode,
            exec,
            num_pes: p_count,
            batch_per_pe: batch,
            cache_per_pe: 128,
            warmup_batches: 1,
            measure_batches: 2,
            seed,
            sampler: SamplerConfig { layers: 2, ..Default::default() },
            ..Default::default()
        };
        let a = engine_run(&ds, &part, &mk(ExecMode::Serial));
        let b = engine_run(&ds, &part, &mk(ExecMode::Threaded));
        prop_assert!(a.s == b.s, "S diverged: {:?} vs {:?}", a.s, b.s);
        prop_assert!(a.e == b.e, "E diverged");
        prop_assert!(a.cross == b.cross, "cross diverged");
        prop_assert!(a.feat_misses == b.feat_misses, "misses diverged");
        prop_assert!(a.cache_miss_rate == b.cache_miss_rate, "miss rate diverged");
        prop_assert!(a.dup_factor == b.dup_factor, "dup diverged");
        Ok(())
    });
}

/// The storage-plane codecs: encoded rows are exactly
/// `codec.row_bytes(dim)` on the wire, f32 round-trips bit-exactly,
/// fp16 is within half-precision rounding (2^-11 relative), and int8 is
/// within half a quantization step of the per-row scale it shipped.
#[test]
fn prop_codec_roundtrip_sizes_and_error_bounds() {
    use coopgnn::feature::Codec;
    check("codec-roundtrip", 0xA12, 40, |rng| {
        let dim = 1 + rng.next_below(512) as usize;
        // magnitudes from ~0.05 to ~20 so the per-row int8 scale varies
        let mag = (rng.next_f64() * 6.0 - 3.0).exp();
        let row: Vec<f32> =
            (0..dim).map(|_| ((rng.next_f64() * 2.0 - 1.0) * mag) as f32).collect();
        for codec in Codec::all() {
            let mut enc = Vec::new();
            codec.encode_row(&row, &mut enc);
            prop_assert!(
                enc.len() == codec.row_bytes(dim),
                "{codec:?}: encoded {} bytes, row_bytes says {}",
                enc.len(),
                codec.row_bytes(dim)
            );
            let mut dec = vec![0f32; dim];
            codec.decode_row(&enc, &mut dec);
            match codec {
                Codec::F32 => {
                    for (i, (&x, &y)) in row.iter().zip(&dec).enumerate() {
                        prop_assert!(x.to_bits() == y.to_bits(), "f32 elem {i} not bit-exact");
                    }
                }
                Codec::Fp16 => {
                    for (i, (&x, &y)) in row.iter().zip(&dec).enumerate() {
                        let bound = (x.abs() as f64) / 2048.0 + 1e-7;
                        prop_assert!(
                            ((x - y).abs() as f64) <= bound,
                            "fp16 elem {i}: {x} -> {y} exceeds 2^-11 relative"
                        );
                    }
                }
                Codec::Int8 => {
                    // the bound is defined by the scale actually shipped
                    let scale = f32::from_le_bytes(enc[0..4].try_into().unwrap());
                    let bound = (scale as f64) * 0.501 + 1e-6;
                    for (i, (&x, &y)) in row.iter().zip(&dec).enumerate() {
                        prop_assert!(
                            ((x - y).abs() as f64) <= bound,
                            "int8 elem {i}: {x} -> {y} outside scale/2 = {}",
                            scale * 0.5
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// The encoded-byte ledger contract under every codec, for random
/// engine shapes: wire bytes are exact multiples of the codec's row
/// size (`bytes_from_storage == cold_fills * row_bytes`,
/// `fabric_bytes == fabric_rows * row_bytes`), the hot tier is charged
/// decoded f32 bytes, and the gathered vertex lists — the count plane —
/// never move with the codec.
#[test]
fn prop_encoded_byte_ledgers_and_codec_invariant_counts() {
    use coopgnn::coop::engine::Mode;
    use coopgnn::feature::Codec;
    use coopgnn::pipeline::{MinibatchStream, PipelineBuilder};
    check("codec-ledgers", 0xA13, 4, |rng| {
        let p_count = 1 + rng.next_below(3) as usize;
        let mode = if rng.next_below(2) == 0 { Mode::Independent } else { Mode::Cooperative };
        let hot_mb = rng.next_below(2) as usize; // 0 = untiered, 1 MiB = tiered
        let batch = 8 + rng.next_below(24) as usize;
        let seed = rng.next_u64();
        let mut baseline: Option<Vec<Vec<u32>>> = None;
        for codec in Codec::all() {
            let pipe = PipelineBuilder::new()
                .dataset("tiny")
                .mode(mode)
                .num_pes(p_count)
                .batch_per_pe(batch)
                .cache_per_pe(128)
                .seed(seed)
                .codec(codec)
                .hot_mb(hot_mb)
                .build()
                .unwrap();
            let store = pipe.feature_store();
            let rb = store.row_bytes() as u64;
            prop_assert!(
                rb == codec.row_bytes(pipe.ds.feat_dim) as u64,
                "{codec:?}: store wire width {rb}"
            );
            let dim = pipe.ds.feat_dim as u64;
            let mut stream = pipe.stream();
            let mut vertex_lists: Vec<Vec<u32>> = Vec::new();
            for batch_i in 0..2 {
                let mb = stream.next_batch();
                for (pe, pw) in mb.per_pe.iter().enumerate() {
                    let ctx = format!("{codec:?}/{mode:?} batch {batch_i} PE {pe}");
                    prop_assert!(pw.row_bytes == rb, "{ctx}: PeWork row_bytes {}", pw.row_bytes);
                    prop_assert!(
                        pw.hot_rows <= pw.misses,
                        "{ctx}: hot fills {} exceed misses {}",
                        pw.hot_rows,
                        pw.misses
                    );
                    prop_assert!(
                        pw.bytes_from_storage == (pw.misses - pw.hot_rows) * rb,
                        "{ctx}: cold fills must be charged wire bytes ({} != ({} - {}) * {rb})",
                        pw.bytes_from_storage,
                        pw.misses,
                        pw.hot_rows
                    );
                    prop_assert!(
                        pw.fabric_bytes == pw.fabric * rb,
                        "{ctx}: fabric bytes {} != rows {} * {rb}",
                        pw.fabric_bytes,
                        pw.fabric
                    );
                    prop_assert!(
                        pw.hot_bytes == pw.hot_rows * dim * 4,
                        "{ctx}: hot tier serves decoded rows ({} != {} * {dim} * 4)",
                        pw.hot_bytes,
                        pw.hot_rows
                    );
                    vertex_lists.push(pw.feature_vertices.clone().unwrap_or_default());
                }
            }
            match &baseline {
                None => baseline = Some(vertex_lists),
                Some(b) => prop_assert!(
                    b == &vertex_lists,
                    "{codec:?}: gathered vertex lists must be codec-invariant"
                ),
            }
        }
        Ok(())
    });
}

/// The observability histograms: for random shard counts, sample
/// mixes (zeros included), and quantiles, [`LogHist::quantile_bounds`]
/// brackets the exact type-7 percentile of the **pooled** samples even
/// when the histogram was built by merging per-shard histograms — the
/// mergeability contract the repro p50/p99 columns rely on.
#[test]
fn prop_hist_quantile_bounds_bracket_pooled_exact_percentile() {
    use coopgnn::obs::LogHist;
    use coopgnn::util::stats::percentile;
    check("hist-bracket", 0xA16, 40, |rng| {
        let shards = 1 + rng.next_below(4) as usize;
        let mut hists = vec![LogHist::new(); shards];
        let mut pooled: Vec<f64> = Vec::new();
        for h in hists.iter_mut() {
            for _ in 0..1 + rng.next_below(120) {
                // zeros, sub-ms, and multi-second magnitudes all mixed
                let v = match rng.next_below(8) {
                    0 => 0.0,
                    1 => rng.next_f64() * 1e-3,
                    _ => (rng.next_f64() * 14.0 - 7.0).exp(),
                };
                h.record(v);
                pooled.push(v);
            }
        }
        let mut merged = LogHist::new();
        for h in &hists {
            merged.merge(h);
        }
        prop_assert!(
            merged.count() == pooled.len() as u64,
            "merge lost samples: {} vs {}",
            merged.count(),
            pooled.len()
        );
        pooled.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut ps = vec![0.0, 0.50, 0.99, 1.0];
        for _ in 0..4 {
            ps.push(rng.next_f64());
        }
        for &p in &ps {
            let exact = percentile(&pooled, p);
            let (lo, hi) = merged.quantile_bounds(p);
            prop_assert!(
                lo <= exact && exact <= hi,
                "p={p}: bracket ({lo}, {hi}) misses exact {exact} \
                 ({} samples in {shards} shards)",
                pooled.len()
            );
            let mid = merged.quantile_mid(p);
            prop_assert!(lo <= mid && mid <= hi, "p={p}: mid {mid} outside bracket");
        }
        Ok(())
    });
}

/// The flight-recorder merge key: across random modes, exec modes,
/// prefetch settings, κ values, and PE counts, every traced engine run
/// yields spans whose `(batch, pe, seq)` keys form a **strict total
/// order** — the property that makes [`TraceBuffer::merged`] and the
/// Chrome export deterministic regardless of track interleaving.
#[test]
fn prop_trace_span_merge_key_is_a_strict_total_order() {
    use coopgnn::coop::engine::{ExecMode, Mode};
    use coopgnn::obs::Trace;
    use coopgnn::pipeline::PipelineBuilder;
    check("trace-total-order", 0xA17, 5, |rng| {
        let mode = if rng.next_below(2) == 0 { Mode::Independent } else { Mode::Cooperative };
        let exec = if rng.next_below(2) == 0 { ExecMode::Serial } else { ExecMode::Threaded };
        let kappa =
            if rng.next_below(2) == 0 { Kappa::Finite(1) } else { Kappa::Finite(16) };
        let pipe = PipelineBuilder::new()
            .dataset("tiny")
            .mode(mode)
            .exec(exec)
            .num_pes(1 + rng.next_below(3) as usize)
            .prefetch(rng.next_below(2) == 1)
            .hot_mb(rng.next_below(2) as usize)
            .kappa(kappa)
            .seed(rng.next_u64())
            .warmup_batches(1)
            .measure_batches(2)
            .build()
            .unwrap();
        let mut trace = Trace::on("engine");
        let _ = pipe.engine_report_traced(&mut trace);
        let buf = trace.buffer().expect("trace was on");
        prop_assert!(buf.span_count() > 0, "{mode:?}/{exec:?}: no spans recorded");
        prop_assert!(
            buf.batch_count() == 2,
            "{mode:?}/{exec:?}: spans must cover exactly the measured batches, got {}",
            buf.batch_count()
        );
        let merged = buf.merged();
        for w in merged.windows(2) {
            prop_assert!(
                (w[0].batch, w[0].pe, w[0].seq) < (w[1].batch, w[1].pe, w[1].seq),
                "{mode:?}/{exec:?}: merge key not strictly increasing \
                 ({:?} then {:?})",
                (w[0].batch, w[0].pe, w[0].seq, w[0].stage),
                (w[1].batch, w[1].pe, w[1].seq, w[1].stage)
            );
        }
        Ok(())
    });
}

#[test]
fn prop_all_reduce_equals_sum_then_broadcast_oracle() {
    use coopgnn::coop::all_to_all::{AllReduceStrategy, Fabric};
    check("all_reduce", 0xA11, 30, |rng| {
        let p = 1 + rng.next_below(6) as usize;
        // lengths below, at, and above the PE count so ring chunking hits
        // empty, single-element, and uneven chunks
        let len = rng.next_below(40) as usize;
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..len).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect())
            .collect();
        // the serial sum-then-broadcast oracle: contributions added in
        // ascending PE order, seeded from PE 0's buffer
        let mut oracle = inputs[0].clone();
        for src in 1..p {
            for (a, &x) in oracle.iter_mut().zip(&inputs[src]) {
                *a += x;
            }
        }
        for strategy in [
            AllReduceStrategy::Naive,
            AllReduceStrategy::Tree,
            AllReduceStrategy::Ring,
            AllReduceStrategy::Rsag,
        ] {
            let endpoints = Fabric::endpoints(p);
            let results: Vec<(Vec<f32>, u64, u64)> = std::thread::scope(|scope| {
                let inputs = &inputs;
                let handles: Vec<_> = endpoints
                    .into_iter()
                    .map(|mut ep| {
                        let mut buf = inputs[ep.pe].clone();
                        scope.spawn(move || {
                            ep.all_reduce_f32(&mut buf, strategy);
                            (buf, ep.cross_grad_reduce_bytes, ep.cross_grad_gather_bytes)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (q, (buf, _, _)) in results.iter().enumerate() {
                prop_assert!(
                    buf.iter().zip(&oracle).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{strategy:?} P={p} len={len} PE {q}: result != oracle"
                );
            }
            // reduce byte accounting matches (num_pes - 1) * payload_bytes
            // per strategy: per endpoint for Naive (full-buffer broadcast),
            // fabric-total for Ring (each element crosses to its owner once)
            let payload = (len * 4) as u64;
            let reduce_total: u64 = results.iter().map(|r| r.1).sum();
            let gather_total: u64 = results.iter().map(|r| r.2).sum();
            match strategy {
                AllReduceStrategy::Naive => {
                    for (q, (_, r, g)) in results.iter().enumerate() {
                        prop_assert!(
                            *r == (p as u64 - 1) * payload,
                            "naive PE {q}: reduce bytes {r} != (P-1)*payload"
                        );
                        prop_assert!(*g == 0, "naive PE {q}: unexpected gather bytes");
                    }
                }
                // tree and the chunked schedules all move the full
                // payload across the fabric once per non-root/owner PE
                // in each phase
                AllReduceStrategy::Tree
                | AllReduceStrategy::Ring
                | AllReduceStrategy::Rsag => {
                    prop_assert!(
                        reduce_total == (p as u64 - 1) * payload,
                        "{strategy:?} reduce total {reduce_total} != (P-1)*payload {payload}*{}",
                        p - 1
                    );
                    prop_assert!(
                        gather_total == (p as u64 - 1) * payload,
                        "{strategy:?} gather total {gather_total} != (P-1)*payload"
                    );
                }
            }
            // the serial reference fabric reports the same result and the
            // same byte totals
            let mut ex = Exchange::new(p);
            let mut serial = inputs.clone();
            ex.all_reduce_f32(&mut serial, strategy);
            for (q, s) in serial.iter().enumerate() {
                prop_assert!(
                    s.iter().zip(&oracle).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{strategy:?} serial PE {q} != oracle"
                );
            }
            prop_assert!(
                ex.cross_grad_reduce_bytes == reduce_total
                    && ex.cross_grad_gather_bytes == gather_total,
                "{strategy:?}: serial byte accounting != endpoint totals"
            );
        }
        Ok(())
    });
}

/// The replicated-fabric all-reduce: at every replica-group size r ∈
/// {1, 2, 4} the result is **bit-identical** to the flat canonical sum
/// (the hierarchical leader chain folds in the same ascending-PE
/// order), serial == threaded, and the inter-group gradient bytes match
/// the closed-form `(P/r - 1) · payload` per phase — the
/// communication-avoiding profile (with r = 1, G = P and the profile
/// degenerates to the flat chunked one).
#[test]
fn prop_hierarchical_all_reduce_bit_identical_with_closed_form_inter_bytes() {
    use coopgnn::coop::all_to_all::{AllReduceStrategy, Fabric, Topology};
    check("hierarchical-all-reduce", 0xA15, 20, |rng| {
        let r = [1usize, 2, 4][rng.next_below(3) as usize];
        let groups = 1 + rng.next_below((8 / r) as u64) as usize;
        let p = r * groups;
        let len = rng.next_below(40) as usize;
        let topo = Topology::new(p, r);
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..len).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect())
            .collect();
        // the flat canonical oracle, cross-checked against the flat
        // Naive and Ring serial fabrics (all three must agree bitwise)
        let mut oracle = inputs[0].clone();
        for src in 1..p {
            for (a, &x) in oracle.iter_mut().zip(&inputs[src]) {
                *a += x;
            }
        }
        for flat in [AllReduceStrategy::Naive, AllReduceStrategy::Ring] {
            let mut ex = Exchange::new(p);
            let mut bufs = inputs.clone();
            ex.all_reduce_f32(&mut bufs, flat);
            for (q, b) in bufs.iter().enumerate() {
                prop_assert!(
                    b.iter().zip(&oracle).all(|(a, o)| a.to_bits() == o.to_bits()),
                    "flat {flat:?} PE {q} != canonical oracle"
                );
            }
        }
        // threaded replicated fabric (at r > 1 the strategy is
        // overridden by the hierarchical leader chain)
        let endpoints = Fabric::endpoints_with(topo);
        let results: Vec<(Vec<f32>, u64, u64, u64, u64)> = std::thread::scope(|scope| {
            let inputs = &inputs;
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    let mut buf = inputs[ep.pe].clone();
                    scope.spawn(move || {
                        ep.all_reduce_f32(&mut buf, AllReduceStrategy::Ring);
                        (
                            buf,
                            ep.cross_grad_reduce_bytes,
                            ep.cross_grad_gather_bytes,
                            ep.inter_grad_reduce_bytes,
                            ep.inter_grad_gather_bytes,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (q, (buf, ..)) in results.iter().enumerate() {
            prop_assert!(
                buf.iter().zip(&oracle).all(|(a, o)| a.to_bits() == o.to_bits()),
                "P={p} r={r} PE {q}: hierarchical result != flat canonical sum"
            );
        }
        // the communication-avoiding closed form, per phase
        let payload = (len * 4) as u64;
        let cross_form = (p as u64 - 1) * payload;
        let inter_form = (groups as u64 - 1) * payload;
        let sum = |i: usize| -> u64 {
            results
                .iter()
                .map(|t| match i {
                    1 => t.1,
                    2 => t.2,
                    3 => t.3,
                    _ => t.4,
                })
                .sum()
        };
        if p > 1 {
            prop_assert!(
                sum(1) == cross_form && sum(2) == cross_form,
                "P={p} r={r}: cross per phase {} / {} != (P-1)*payload {cross_form}",
                sum(1),
                sum(2)
            );
        }
        prop_assert!(
            sum(3) == inter_form && sum(4) == inter_form,
            "P={p} r={r}: inter per phase {} / {} != (P/r-1)*payload {inter_form}",
            sum(3),
            sum(4)
        );
        // serial twin: same result bits, same ledger totals
        let mut ex = Exchange::with_topology(topo);
        let mut bufs = inputs.clone();
        ex.all_reduce_f32(&mut bufs, AllReduceStrategy::Ring);
        for (q, b) in bufs.iter().enumerate() {
            prop_assert!(
                b.iter().zip(&oracle).all(|(a, o)| a.to_bits() == o.to_bits()),
                "P={p} r={r} serial PE {q} != oracle"
            );
        }
        prop_assert!(
            ex.inter_grad_reduce_bytes == sum(3) && ex.inter_grad_gather_bytes == sum(4),
            "P={p} r={r}: serial inter ledgers != threaded totals"
        );
        prop_assert!(
            ex.cross_grad_reduce_bytes == sum(1) && ex.cross_grad_gather_bytes == sum(2),
            "P={p} r={r}: serial cross ledgers != threaded totals"
        );
        Ok(())
    });
}
