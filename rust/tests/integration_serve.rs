//! Serving-plane determinism gates (the PR's acceptance criteria):
//!
//! * the full request-latency ledger — admissions, virtual timestamps,
//!   batch assignment — and the prediction checksum are **bit-identical**
//!   across `--exec serial|threaded` at a fixed seed;
//! * likewise across `--prefetch 0|1` (prediction prefetching overlaps
//!   real CPU work, never virtual time);
//! * batcher admission never violates FIFO order within a requester
//!   (property-tested over randomized rates/SLOs/policies/modes).

use coopgnn::coop::engine::{ExecMode, Mode};
use coopgnn::pipeline::PipelineBuilder;
use coopgnn::prop_assert;
use coopgnn::serve::{BatcherKind, Ledger, ServeConfig, WorkloadKind};
use coopgnn::util::propcheck::check;

#[allow(clippy::too_many_arguments)]
fn run_serve(
    mode: Mode,
    exec: ExecMode,
    prefetch: bool,
    batcher: BatcherKind,
    workload: WorkloadKind,
    pes: usize,
    seed: u64,
    rate: f64,
    slo_us: u64,
    fixed_per_pe: usize,
    duration: usize,
) -> Ledger {
    let pipe = PipelineBuilder::new()
        .dataset("tiny")
        .mode(mode)
        .exec(exec)
        .num_pes(pes)
        .prefetch(prefetch)
        .seed(seed)
        .build()
        .unwrap();
    let scfg = ServeConfig {
        rate_per_s: rate,
        slo_us,
        batcher,
        duration_batches: duration,
        fixed_batch_per_pe: fixed_per_pe,
        workload,
        clients: 16,
        ..Default::default()
    };
    pipe.server(scfg).unwrap().run().ledger
}

fn assert_ledgers_identical(a: &Ledger, b: &Ledger, label: &str) {
    assert_eq!(a.requests.len(), b.requests.len(), "{label}: served counts");
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert_eq!(x, y, "{label}: request records must match bit-for-bit");
    }
    assert_eq!(a.batches.len(), b.batches.len(), "{label}: batch counts");
    for (x, y) in a.batches.iter().zip(&b.batches) {
        assert_eq!(
            (x.index, x.size, x.dispatch_us, x.service_us, x.storage_bytes, x.fabric_bytes),
            (y.index, y.size, y.dispatch_us, y.service_us, y.storage_bytes, y.fabric_bytes),
            "{label}: batch records must match"
        );
    }
    assert_eq!(a.dropped, b.dropped, "{label}: drop accounting");
    assert_eq!(a.checksum(), b.checksum(), "{label}: ledger checksum");
}

/// The headline determinism gate: serial and threaded execution produce
/// the identical ledger (timestamps + admissions + predictions), for
/// both modes and both batchers.
#[test]
fn serial_and_threaded_ledgers_are_bit_identical() {
    for mode in [Mode::Independent, Mode::Cooperative] {
        for batcher in [BatcherKind::Fixed, BatcherKind::Adaptive] {
            let serial = run_serve(
                mode,
                ExecMode::Serial,
                false,
                batcher,
                WorkloadKind::OpenPoisson,
                2,
                13,
                15_000.0,
                25_000,
                8,
                8,
            );
            let threaded = run_serve(
                mode,
                ExecMode::Threaded,
                false,
                batcher,
                WorkloadKind::OpenPoisson,
                2,
                13,
                15_000.0,
                25_000,
                8,
                8,
            );
            assert!(serial.requests.len() > 8, "{mode:?}/{batcher:?}: sim must serve requests");
            assert_ledgers_identical(&serial, &threaded, &format!("{mode:?}/{batcher:?}"));
        }
    }
}

/// Prediction prefetching overlaps batch t's forward pass with batch
/// t+1's admission on real threads — and must be invisible in virtual
/// time and in the predictions.
#[test]
fn prefetch_on_off_ledgers_are_bit_identical() {
    for mode in [Mode::Independent, Mode::Cooperative] {
        for exec in [ExecMode::Serial, ExecMode::Threaded] {
            let off = run_serve(
                mode,
                exec,
                false,
                BatcherKind::Adaptive,
                WorkloadKind::OpenPoisson,
                3,
                29,
                12_000.0,
                30_000,
                8,
                7,
            );
            let on = run_serve(
                mode,
                exec,
                true,
                BatcherKind::Adaptive,
                WorkloadKind::OpenPoisson,
                3,
                29,
                12_000.0,
                30_000,
                8,
                7,
            );
            assert_ledgers_identical(&off, &on, &format!("{mode:?}/{exec:?} prefetch"));
        }
    }
}

/// Closed-loop runs are deterministic too (completions feed arrivals,
/// so admission timing feeds back into the workload).
#[test]
fn closed_loop_serial_threaded_identical() {
    let a = run_serve(
        Mode::Cooperative,
        ExecMode::Serial,
        false,
        BatcherKind::Fixed,
        WorkloadKind::ClosedLoop,
        2,
        41,
        8_000.0,
        20_000,
        4,
        6,
    );
    let b = run_serve(
        Mode::Cooperative,
        ExecMode::Threaded,
        true,
        BatcherKind::Fixed,
        WorkloadKind::ClosedLoop,
        2,
        41,
        8_000.0,
        20_000,
        4,
        6,
    );
    assert!(a.requests.len() > 4);
    assert_ledgers_identical(&a, &b, "closed loop");
}

/// Property: batcher admission never violates FIFO order within a
/// requester — if request A of a client arrived before request B, A is
/// dispatched no later than B (and in no later a batch), across
/// randomized rates, SLOs, policies, modes, and workloads.
#[test]
fn prop_admission_preserves_fifo_per_requester() {
    check("serve-fifo", 0x5E12, 10, |rng| {
        let mode =
            if rng.next_below(2) == 0 { Mode::Independent } else { Mode::Cooperative };
        let batcher =
            if rng.next_below(2) == 0 { BatcherKind::Fixed } else { BatcherKind::Adaptive };
        let workload = if rng.next_below(2) == 0 {
            WorkloadKind::OpenPoisson
        } else {
            WorkloadKind::ClosedLoop
        };
        let rate = 2_000.0 + rng.next_f64() * 30_000.0;
        let slo_us = 5_000 + rng.next_below(60_000);
        let fixed = 2 + rng.next_below(24) as usize;
        let pes = 2 + rng.next_below(2) as usize;
        let duration = 4 + rng.next_below(4) as usize;
        let ledger = run_serve(
            mode,
            ExecMode::Threaded,
            false,
            batcher,
            workload,
            pes,
            rng.next_u64(),
            rate,
            slo_us,
            fixed,
            duration,
        );
        prop_assert!(!ledger.requests.is_empty(), "sim served nothing");
        // BTreeMap: clients are checked (and reported on failure) in
        // id order, not hash order
        let mut by_requester: std::collections::BTreeMap<u32, Vec<_>> = Default::default();
        for r in &ledger.requests {
            by_requester.entry(r.requester).or_default().push(*r);
        }
        for (client, mut rs) in by_requester {
            rs.sort_by_key(|r| (r.arrival_us, r.id));
            for w in rs.windows(2) {
                prop_assert!(
                    w[0].dispatch_us <= w[1].dispatch_us,
                    "client {client}: request {} (arrived {}) dispatched at {} after \
                     request {} (arrived {}) dispatched at {}",
                    w[0].id,
                    w[0].arrival_us,
                    w[0].dispatch_us,
                    w[1].id,
                    w[1].arrival_us,
                    w[1].dispatch_us
                );
                prop_assert!(
                    w[0].batch <= w[1].batch,
                    "client {client}: batch order inverted ({} vs {})",
                    w[0].batch,
                    w[1].batch
                );
                prop_assert!(w[0].id < w[1].id, "ids must follow arrival order");
            }
        }
        Ok(())
    });
}
