//! PJRT integration: load the AOT artifacts, execute train/forward, and
//! verify end-to-end numerics (loss descent, eval plumbing). Requires
//! `make artifacts` to have run (skipped with a message otherwise).

use coopgnn::coop::engine::ExecMode;
use coopgnn::graph::datasets;
use coopgnn::pipeline::{Batching, TrainStream};
use coopgnn::runtime::{Manifest, Runtime};
use coopgnn::sampling::{Kappa, SamplerConfig, SamplerKind};
use coopgnn::train::{Trainer, TrainerOptions};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(Box::leak(p.into_boxed_path()))
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

/// Execution-runtime gate: this build may ship the PJRT stub, in which
/// case every runtime-dependent test skips (even when artifacts exist).
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn manifest_loads_and_is_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    assert!(m.configs.len() >= 5, "expected >=5 configs, got {}", m.configs.len());
    let tiny = m.get("tiny-b32").unwrap();
    assert_eq!(tiny.dataset, "tiny");
    assert_eq!(tiny.caps.n.len(), tiny.layers + 1);
    assert_eq!(tiny.num_train_inputs, 3 * 6 + 1 + 1 + 4 * 3 + 3);
    assert!(tiny.train_hlo.exists());
    assert!(tiny.forward_hlo.exists());
}

#[test]
fn train_step_executes_and_loss_decreases() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let ds = datasets::build("tiny", 1).unwrap();
    let opts = TrainerOptions {
        kind: SamplerKind::Labor0,
        kappa: Kappa::Finite(1),
        lr: Some(0.02),
        ..Default::default()
    };
    let mut t = Trainer::new(&rt, &manifest, "tiny-b32", &ds, &opts).unwrap();
    let mut first_losses = Vec::new();
    let mut last_losses = Vec::new();
    let steps = 200;
    for i in 0..steps {
        let s = t.step().unwrap();
        assert!(s.loss.is_finite(), "step {i} loss {}", s.loss);
        if i < 20 {
            first_losses.push(s.loss as f64);
        }
        if i >= steps - 20 {
            last_losses.push(s.loss as f64);
        }
    }
    let first: f64 = first_losses.iter().sum::<f64>() / first_losses.len() as f64;
    let last: f64 = last_losses.iter().sum::<f64>() / last_losses.len() as f64;
    // The planted task has an irreducible noise floor; require a clear
    // but modest descent here — the `evaluate_runs_and_improves...` test
    // checks generalization strength.
    assert!(
        last < first * 0.97,
        "loss should decrease: first20 {first:.4} last20 {last:.4}"
    );
    assert_eq!(t.state.step, steps as f32, "Adam step counter advanced in-graph");
}

#[test]
fn evaluate_runs_and_improves_over_random() {
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let ds = datasets::build("tiny", 2).unwrap();
    let opts = TrainerOptions { lr: Some(0.02), ..Default::default() };
    let mut t = Trainer::new(&rt, &manifest, "tiny-b32", &ds, &opts).unwrap();
    let val: Vec<u32> = ds.val.clone();
    let before = t.evaluate(&val, 99).unwrap();
    for _ in 0..80 {
        t.step().unwrap();
    }
    let after = t.evaluate(&val, 99).unwrap();
    let chance = 1.0 / ds.num_classes as f64;
    assert!(
        after.accuracy > before.accuracy.max(chance * 1.5),
        "val accuracy should improve: before {:.3} after {:.3} (chance {:.3})",
        before.accuracy,
        after.accuracy,
        chance
    );
    assert!(after.macro_f1 > 0.0);
}

#[test]
fn merged_indep_mfg_executes() {
    // The merged block-diagonal MFG (Figure 9 indep baseline) must fit
    // and execute with the tiny caps when merging 2 sub-batches of 16 —
    // built through the pipeline stream and fed to the trainer via
    // step_from.
    let Some(dir) = artifacts_dir() else { return };
    let Some(rt) = runtime() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let ds = datasets::build("tiny", 3).unwrap();
    let opts = TrainerOptions { lr: Some(0.02), ..Default::default() };
    let mut t = Trainer::new(&rt, &manifest, "tiny-b32", &ds, &opts).unwrap();
    let mut stream = TrainStream::new(
        &ds,
        SamplerKind::Labor0,
        SamplerConfig { layers: t.art.layers, ..Default::default() },
        32,
        7,
        ExecMode::Threaded,
        Batching::IndepMerged { pes: 2 },
    );
    let s = t.step_from(&mut stream).unwrap();
    assert!(s.loss.is_finite());
    eprintln!("merged step: loss={} truncated_v={}", s.loss, s.truncated_vertices);
}
