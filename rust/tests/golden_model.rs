//! Golden-vector parity: the Rust host compute plane vs the Python
//! model (`python/compile/model.py`).
//!
//! `python/tests/export_golden.py` runs the authoritative jax model on
//! a fixed-seed 2-layer batch and freezes every input and expected
//! output into `tests/data/golden_model.txt`. This test replays the
//! identical batch through the host backend — `Predictor` forward,
//! `PeStep` loss/backward, `ParamState::adam_step` — and asserts the
//! logits, masked-mean loss, correct count, per-parameter gradients,
//! and post-Adam parameters all agree within 1e-5. This is the
//! cross-language contract behind the `GnnModel` seam: a training run
//! moves parameters the same way no matter which backend executes it.

use coopgnn::model::{HostBlock, ModelDims, PeCompute, Predictor};
use coopgnn::model::host::PeStep;
use coopgnn::runtime::tensors::ParamState;
use std::collections::HashMap;

const TOL: f32 = 1e-5;

struct Golden {
    vals: HashMap<String, Vec<f64>>,
}

impl Golden {
    fn load() -> Golden {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/golden_model.txt");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("read {path}: {e} (regenerate with python/tests/export_golden.py)"));
        let mut vals = HashMap::new();
        for line in text.lines() {
            if line.starts_with('#') || line.trim().is_empty() {
                continue;
            }
            let (name, rest) = line.split_once(':').expect("golden line format `name: v v ...`");
            let v: Vec<f64> = rest
                .split_whitespace()
                .map(|t| t.parse().unwrap_or_else(|e| panic!("{name}: bad float {t:?}: {e}")))
                .collect();
            vals.insert(name.trim().to_string(), v);
        }
        Golden { vals }
    }

    fn f64s(&self, name: &str) -> &[f64] {
        self.vals.get(name).unwrap_or_else(|| panic!("golden file missing `{name}`"))
    }

    fn f32s(&self, name: &str) -> Vec<f32> {
        self.f64s(name).iter().map(|&v| v as f32).collect()
    }

    fn usizes(&self, name: &str) -> Vec<usize> {
        self.f64s(name).iter().map(|&v| v as usize).collect()
    }

    fn scalar(&self, name: &str) -> f64 {
        let v = self.f64s(name);
        assert_eq!(v.len(), 1, "`{name}` is a scalar");
        v[0]
    }

    fn dims(&self) -> ModelDims {
        let d = self.usizes("dims");
        assert_eq!(d.len(), 4, "dims = layers d_in hidden classes");
        ModelDims { layers: d[0], d_in: d[1], hidden: d[2], classes: d[3] }
    }

    /// Rebuild the unpadded CSR block from the padded golden arrays:
    /// a neighbor slot is a real edge iff its weight is nonzero.
    fn block(&self, l: usize, n_dst: usize, n_src: usize, k: usize) -> HostBlock {
        let nbr_idx = self.usizes(&format!("block{l}_nbr_idx"));
        let nbr_w = self.f32s(&format!("block{l}_nbr_w"));
        let self_idx = self.usizes(&format!("block{l}_self_idx"));
        let self_w = self.f32s(&format!("block{l}_self_w"));
        assert_eq!(nbr_idx.len(), n_dst * k, "block {l} nbr_idx shape");
        assert_eq!(self_idx.len(), n_dst, "block {l} self_idx shape");
        let mut b = HostBlock {
            n_dst,
            n_src,
            offsets: vec![0],
            nbr_pos: Vec::new(),
            nbr_w: Vec::new(),
            self_pos: self_idx.iter().map(|&i| i as u32).collect(),
            self_w,
        };
        for i in 0..n_dst {
            for j in 0..k {
                if nbr_w[i * k + j] != 0.0 {
                    b.nbr_pos.push(nbr_idx[i * k + j] as u32);
                    b.nbr_w.push(nbr_w[i * k + j]);
                }
            }
            b.offsets.push(b.nbr_pos.len() as u32);
        }
        b
    }

    fn params(&self, prefix: &str, dims: &ModelDims) -> Vec<Vec<f32>> {
        dims.param_shapes()
            .iter()
            .enumerate()
            .map(|(i, shape)| {
                let p = self.f32s(&format!("{prefix}{i}"));
                assert_eq!(p.len(), shape.iter().product::<usize>(), "{prefix}{i} shape");
                p
            })
            .collect()
    }
}

fn assert_close(name: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{name} length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= TOL,
            "{name}[{i}]: rust {g} vs python {w} (|Δ| = {:.3e} > {TOL:.0e})",
            (g - w).abs()
        );
    }
}

#[test]
fn host_backend_matches_python_golden_vectors() {
    let g = Golden::load();
    let dims = g.dims();
    let k = g.scalar("k") as usize;
    let n = g.usizes("n");
    let lr = g.scalar("lr") as f32;
    assert_eq!(n.len(), dims.layers + 1, "layer widths");

    let feats = g.f32s("feats");
    assert_eq!(feats.len(), n[dims.layers] * dims.d_in, "feature buffer shape");
    let labels: Vec<u16> = g.usizes("labels").iter().map(|&v| v as u16).collect();
    let params = g.params("param", &dims);

    let blocks: Vec<HostBlock> =
        (0..dims.layers).map(|l| g.block(l, n[l], n[l + 1], k)).collect();
    let comp = PeCompute {
        blocks,
        seeds: (0..n[0] as u32).collect(),
        routes: None,
    };

    // forward logits through the public prediction path
    let pred = Predictor::new(dims, params.clone());
    let logits = pred.logits_minibatch(&[(&comp, &feats)]);
    assert_eq!(logits.len(), 1, "one PE");
    assert_close("logits", &logits[0], &g.f32s("logits"));

    // loss / correct / gradients through the training path
    // with_shapes zero-inits m/v and step; only the params are golden
    let mut state = ParamState::with_shapes(dims.param_shapes(), 0);
    state.params = params;

    let mut flat = vec![0f32; state.num_scalars()];
    let (loss_sum, correct, examples) = {
        let mut step = PeStep::new(dims, &comp, &feats, &state.params);
        step.forward_deepest();
        for l in (0..dims.layers - 1).rev() {
            step.forward_level(l, None);
        }
        let head = step.loss_grad(&labels);
        for l in 0..dims.layers {
            step.backward_level(l, &mut flat);
        }
        head
    };
    assert_eq!(examples, n[0] as f32, "seed count");
    assert!(
        (loss_sum / examples - g.scalar("loss") as f32).abs() <= TOL,
        "loss: rust {} vs python {}",
        loss_sum / examples,
        g.scalar("loss")
    );
    assert_eq!(correct, g.scalar("correct") as f32, "correct count");

    // python's jax.grad of the masked-*mean* loss is already 1/n-scaled
    for v in flat.iter_mut() {
        *v /= examples;
    }
    let mut off = 0;
    for (i, shape) in dims.param_shapes().iter().enumerate() {
        let len: usize = shape.iter().product();
        assert_close(&format!("grad{i}"), &flat[off..off + len], &g.f32s(&format!("grad{i}")));
        off += len;
    }

    // one bias-corrected Adam step moves the parameters identically
    state.adam_step(&flat, lr);
    assert_eq!(state.step, 1.0, "adam timestep");
    for (i, p) in state.params.iter().enumerate() {
        assert_close(&format!("new_param{i}"), p, &g.f32s(&format!("new_param{i}")));
    }
}
