//! Cross-module integration tests: datasets × samplers × blocks, and the
//! cap-planning consistency with the AOT manifest configs.

use coopgnn::graph::datasets;
use coopgnn::sampling::{block, Kappa, SamplerConfig, SamplerKind};

/// Print measured shape caps for the artifact configs (run with
/// `cargo test --release --test integration_sampling -- --nocapture caps_report`).
/// The numbers frozen in python/compile/aot.py CONFIGS must dominate these.
#[test]
fn caps_report() {
    for (ds_name, batch) in
        [("tiny", 32), ("conv", 256), ("conv", 1024), ("papers-s", 256), ("papers-s", 1024)]
    {
        let ds = datasets::build(ds_name, 1).unwrap();
        let cfg = SamplerConfig { kappa: Kappa::Finite(1), ..Default::default() };
        // Bound caps with the least concave sampler (NS) and the
        // trainer's sampler (LABOR-0).
        for kind in [SamplerKind::Neighbor, SamplerKind::Labor0] {
            let train: Vec<u32> = if ds.train.len() >= batch {
                ds.train.clone()
            } else {
                (0..ds.graph.num_vertices() as u32).collect()
            };
            let caps = block::estimate_caps(&cfg, kind, &ds.graph, &train, batch, 5, 1.25, 42);
            println!("caps {ds_name} b={batch} {}: k={} n={:?}", kind.name(), caps.k, caps.n);
        }
    }
}

#[test]
fn mfg_on_every_registry_dataset_small_batch() {
    for spec in datasets::SPECS.iter().filter(|s| s.num_vertices <= 100_000) {
        let ds = datasets::build(spec.name, 3).unwrap();
        let cfg = SamplerConfig::default();
        let mut s = cfg.build(SamplerKind::Labor0, &ds.graph, 9);
        let seeds: Vec<u32> = ds.train.iter().take(64).copied().collect();
        if seeds.is_empty() {
            continue;
        }
        let mfg = s.sample_mfg(&seeds);
        assert_eq!(mfg.num_layers(), 3);
        assert!(mfg.total_vertices() >= seeds.len());
    }
}

#[test]
fn work_per_seed_decreases_with_batch_size_theorem31() {
    // Empirical Theorem 3.1 on a registry dataset: E|S^3|/|S^0| is
    // monotone nonincreasing in |S^0|.
    let ds = datasets::build("tiny", 5).unwrap();
    let cfg = SamplerConfig::default();
    let n = ds.graph.num_vertices();
    let mut prev = f64::INFINITY;
    for &b in &[16usize, 64, 256, 1024] {
        let mut acc = 0.0;
        let trials = 8;
        for t in 0..trials {
            let mut s = cfg.build(SamplerKind::Labor0, &ds.graph, 100 + t);
            let seeds: Vec<u32> = (0..n as u32).step_by(n / b).take(b).collect();
            let mfg = s.sample_mfg(&seeds);
            acc += mfg.input_vertices().len() as f64 / seeds.len() as f64;
        }
        let ratio = acc / trials as f64;
        assert!(
            ratio <= prev * 1.05,
            "work ratio must not increase: b={b} ratio={ratio} prev={prev}"
        );
        prev = ratio;
    }
}

#[test]
fn dependent_batches_overlap_more_than_independent() {
    // κ=64 consecutive batches share far more of S^3 than κ=1 batches —
    // the locality mechanism behind Figure 5.
    let ds = datasets::build("tiny", 7).unwrap();
    let overlap = |kappa: Kappa| -> f64 {
        let cfg = SamplerConfig { kappa, ..Default::default() };
        let mut s = cfg.build(SamplerKind::Labor0, &ds.graph, 11);
        let seeds: Vec<u32> = ds.train.iter().take(64).copied().collect();
        let a: std::collections::HashSet<u32> =
            s.sample_mfg(&seeds).input_vertices().iter().copied().collect();
        s.advance_batch();
        let b: std::collections::HashSet<u32> =
            s.sample_mfg(&seeds).input_vertices().iter().copied().collect();
        a.intersection(&b).count() as f64 / a.len().max(1) as f64
    };
    let o1 = overlap(Kappa::Finite(1));
    let o64 = overlap(Kappa::Finite(64));
    let oinf = overlap(Kappa::Infinite);
    assert!(o64 > o1, "κ=64 overlap {o64} must beat κ=1 {o1}");
    assert!(oinf > 0.999, "κ=∞ batches identical, got {oinf}");
}
